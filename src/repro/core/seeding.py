"""Minimap2-style seeding (paper §III-B): minimizers -> hash lookup -> sort.

The paper's seeding stage extracts window minimizers from the read, indexes
a hash table built over the reference, and radix-sorts the resulting
(query_pos, ref_pos) anchors by reference position — the sort dominating
runtime is exactly the chunk-parallel sort of core/sort.py.

TPU adaptation of the sparse structures: the hash table becomes two sorted
arrays (hash, position) queried with vectorized binary search
(searchsorted); variable-length outputs become fixed-capacity arrays with
validity masks (the standard TPU replacement for dynamic sizes; same
pattern the MoE capacity dispatch uses).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sort as rsort

Array = jnp.ndarray


def hash32(x: Array) -> Array:
    """Murmur3 finalizer (invertible mix) on uint32, wraps mod 2^32."""
    x = x.astype(jnp.uint32)
    x ^= x >> 16
    x *= jnp.uint32(0x85EBCA6B)
    x ^= x >> 13
    x *= jnp.uint32(0xC2B2AE35)
    x ^= x >> 16
    return x


def kmer_codes(seq: Array, k: int) -> Array:
    """2-bit pack k-mers: seq (n,) int in 0..3 -> (n-k+1,) uint32. k <= 15."""
    n = seq.shape[0]
    nk = n - k + 1
    code = jnp.zeros((nk,), jnp.uint32)
    for t in range(k):
        code = (code << 2) | seq[t:t + nk].astype(jnp.uint32)
    return code


def minimizers(seq: Array, k: int, w: int) -> Tuple[Array, Array, Array]:
    """Window minimizers: for each window of w consecutive k-mers, the
    k-mer with the smallest hash (leftmost on ties).

    Returns fixed-size (positions, hashes, valid) of length n-k-w+2 with
    duplicate consecutive minimizers masked out (robust winnowing's
    compaction, as a mask).
    """
    codes = kmer_codes(seq, k)
    h = hash32(codes)
    nk = h.shape[0]
    nw = nk - w + 1
    # stack the w shifted views: (w, nw)
    stacked = jnp.stack([h[t:t + nw] for t in range(w)], axis=0)
    arg = jnp.argmin(stacked, axis=0)             # leftmost min per window
    pos = arg + jnp.arange(nw)                    # k-mer position
    hmin = jnp.min(stacked, axis=0)
    # consecutive windows often pick the same k-mer -> keep first occurrence
    keep = jnp.concatenate(
        [jnp.ones((1,), bool), pos[1:] != pos[:-1]])
    return pos, hmin, keep


class Index(NamedTuple):
    """Reference minimizer index: hash-sorted arrays + bucket boundaries."""
    hashes: Array     # (n_idx,) uint32, sorted
    positions: Array  # (n_idx,) int32 reference positions, grouped by hash


def build_index(ref: np.ndarray, k: int, w: int) -> Index:
    """Host-side (offline) index construction, like minimap2's indexing."""
    pos, h, keep = jax.jit(minimizers, static_argnums=(1, 2))(
        jnp.asarray(ref), k, w)
    pos, h, keep = np.asarray(pos), np.asarray(h), np.asarray(keep)
    pos, h = pos[keep], h[keep]
    order = np.argsort(h, kind="stable")
    return Index(hashes=jnp.asarray(h[order]),
                 positions=jnp.asarray(pos[order].astype(np.int32)))


def lookup_anchors(index: Index, qpos: Array, qhash: Array, qvalid: Array,
                   max_occ: int = 8):
    """Vectorized hash-table probe -> fixed-capacity anchor set.

    For each query minimizer, up to `max_occ` reference hits become anchors
    (q_pos, r_pos). Returns (q, r, valid) of shape (n_min * max_occ,).
    """
    lo = jnp.searchsorted(index.hashes, qhash, side="left")
    hi = jnp.searchsorted(index.hashes, qhash, side="right")
    occ = jnp.arange(max_occ)[None, :]                     # (1, C)
    slot = lo[:, None] + occ                               # (n, C)
    hit = (slot < hi[:, None]) & qvalid[:, None]
    slot = jnp.clip(slot, 0, index.positions.shape[0] - 1)
    r = index.positions[slot]
    q = jnp.broadcast_to(qpos[:, None], r.shape)
    return (q.reshape(-1).astype(jnp.int32),
            r.reshape(-1).astype(jnp.int32),
            hit.reshape(-1))


def seed(index: Index, read: Array, k: int, w: int, max_occ: int = 8,
         num_sort_chunks: int = 8, valid_len: Array | None = None):
    """Full seeding stage: minimizers -> lookup -> radix sort by r_pos.

    ``valid_len``: true read length when ``read`` is padded to a shape
    bucket (fixed-shape pipelines); minimizers beyond it are masked.
    Invalid anchors get key uint32.max so they sort to the tail; returns
    (q_sorted, r_sorted, valid_sorted).
    """
    qpos, qh, qvalid = minimizers(read, k, w)
    if valid_len is not None:
        # windows are indexed by position in the minimizer arrays; only
        # windows fully inside the true read are real (n_windows =
        # valid_len - k - w + 2), which makes padded == unpadded exactly.
        n_windows = valid_len - k - w + 2
        qvalid &= jnp.arange(qpos.shape[0]) < n_windows
    q, r, valid = lookup_anchors(index, qpos, qh, qvalid, max_occ)
    key = jnp.where(valid, r.astype(jnp.uint32),
                    jnp.uint32(0xFFFFFFFF))
    packed = (q.astype(jnp.uint32) << 1) | valid.astype(jnp.uint32)
    rk, pv = rsort.radix_sort(key, packed.astype(jnp.int32),
                              num_chunks=num_sort_chunks,
                              min_parallel=0)
    pv = pv.astype(jnp.uint32)
    q_sorted = (pv >> 1).astype(jnp.int32)
    valid_sorted = (pv & 1).astype(bool) & (rk != jnp.uint32(0xFFFFFFFF))
    r_sorted = rk.astype(jnp.int32)
    return q_sorted, r_sorted, valid_sorted
