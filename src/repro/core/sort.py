"""Chunk-parallel radix sort + parallel merge (paper §III-A, Alg. 1).

Squire splits the array across workers, each worker runs a scalar LSD radix
sort on its chunk, and the host merges the sorted chunks with a min-heap.
TPU adaptation:

  * chunk sort  — vmapped over chunks ("workers"); each pass is a *stable
    counting sort* realized with data-parallel primitives: one-hot bucket
    matrix -> per-bucket exclusive prefix sums give every element its rank
    (this replaces the scalar inner loop; the cumsum is the fine-grain
    parallel structure).
  * merge       — the sequential min-heap merge becomes a parallel merge:
    position of a[i] in merge(a,b) is i + searchsorted(b, a[i]); log2(W)
    pairwise rounds replace the heap. Exact and stable.

Supports an optional value array (sort-by-key), which seeding/chaining use
to carry query positions alongside reference positions.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray

RADIX_BITS = 8
RADIX = 1 << RADIX_BITS


def _counting_pass(keys: Array, vals: Array, shift: int) -> Tuple[Array, Array]:
    """One stable LSD pass on a single chunk (uint32 keys)."""
    n = keys.shape[0]
    bucket = (keys >> shift) & (RADIX - 1)                    # (n,)
    onehot = jax.nn.one_hot(bucket, RADIX, dtype=jnp.int32)   # (n, R)
    within = jnp.cumsum(onehot, axis=0) - onehot              # rank in bucket
    counts = jnp.sum(onehot, axis=0)                          # (R,)
    starts = jnp.cumsum(counts) - counts                      # exclusive scan
    pos = starts[bucket] + jnp.take_along_axis(
        within, bucket[:, None], axis=1)[:, 0]
    out_k = jnp.zeros_like(keys).at[pos].set(keys)
    out_v = jnp.zeros_like(vals).at[pos].set(vals)
    return out_k, out_v


def radix_sort_chunk(keys: Array, vals: Array, key_bits: int = 32
                     ) -> Tuple[Array, Array]:
    """Full LSD radix sort of one chunk (the per-worker kernel)."""
    for shift in range(0, key_bits, RADIX_BITS):
        keys, vals = _counting_pass(keys, vals, shift)
    return keys, vals


def merge_sorted(ak: Array, av: Array, bk: Array, bv: Array
                 ) -> Tuple[Array, Array]:
    """Stable parallel merge of two sorted (key, value) arrays."""
    na, nb = ak.shape[0], bk.shape[0]
    pos_a = jnp.arange(na) + jnp.searchsorted(bk, ak, side="left")
    pos_b = jnp.arange(nb) + jnp.searchsorted(ak, bk, side="right")
    nk = jnp.zeros((na + nb,), ak.dtype)
    nv = jnp.zeros((na + nb,), av.dtype)
    nk = nk.at[pos_a].set(ak).at[pos_b].set(bk)
    nv = nv.at[pos_a].set(av).at[pos_b].set(bv)
    return nk, nv


def radix_sort(keys: Array, vals: Optional[Array] = None,
               num_chunks: int = 8, key_bits: int = 32,
               min_parallel: int = 10_000):
    """Chunk-parallel radix sort (Alg. 1). Exact vs jnp.sort.

    Like the paper (line 2 of Alg. 1), arrays below `min_parallel` skip the
    worker path and sort in one chunk — chunking overhead dominates below
    ~10k elements on Squire, and below one tile here.
    """
    if vals is None:
        vals = jnp.arange(keys.shape[0], dtype=jnp.int32)
    n = keys.shape[0]
    if n < min_parallel or num_chunks == 1:
        return radix_sort_chunk(keys, vals, key_bits)

    # pad to a multiple of num_chunks with +inf-like keys (sort to the end)
    pad = (-n) % num_chunks
    maxk = jnp.array(jnp.iinfo(keys.dtype).max, keys.dtype)
    if pad:
        keys = jnp.concatenate([keys, jnp.full((pad,), maxk, keys.dtype)])
        vals = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    lc = keys.shape[0] // num_chunks

    kc = keys.reshape(num_chunks, lc)
    vc = vals.reshape(num_chunks, lc)
    kc, vc = jax.vmap(partial(radix_sort_chunk, key_bits=key_bits))(kc, vc)

    # log2 rounds of pairwise merges
    chunks = [(kc[i], vc[i]) for i in range(num_chunks)]
    while len(chunks) > 1:
        nxt = []
        for i in range(0, len(chunks) - 1, 2):
            nxt.append(merge_sorted(*chunks[i], *chunks[i + 1]))
        if len(chunks) % 2:
            nxt.append(chunks[-1])
        chunks = nxt
    out_k, out_v = chunks[0]
    return out_k[:n], out_v[:n]


def sort_i32(keys: Array, vals: Optional[Array] = None, **kw):
    """Signed int32 sort: flipping the sign bit maps int32 order onto
    uint32 order (works without x64)."""
    sign = jnp.uint32(0x80000000)
    uk = jax.lax.bitcast_convert_type(keys, jnp.uint32) ^ sign
    ok, ov = radix_sort(uk, vals, **kw)
    return jax.lax.bitcast_convert_type(ok ^ sign, jnp.int32), ov
