"""Semirings for dependency-bound recurrences.

Squire's synchronization counters order the *consumption* of previously
produced values (``f(j)`` in the chain kernel, boundary cells in DTW/SW).
On TPU we replace the ordering hardware with algebra: every kernel the paper
accelerates is an affine recurrence

    x_t = (a_t (*) x_{t-1}) (+) b_t

over some semiring ``((+), (*))`` — (max,+) for chain/Smith-Waterman,
(min,+) for DTW, ordinary (+,*) for the diagonal-linear SSM scans that power
RWKV6/Mamba. Affine elements compose associatively, which is what lets the
1-D engine (scan1d) run the recurrence sequentially, chunked (Squire's
worker partitioning) or as a parallel associative scan (beyond-paper).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A commutative-monoid pair ((+), (*)) with (+)-identity ``zero``.

    ``add`` is the "combining" op (max / min / +), ``mul`` the "extending"
    op (+ / *). ``one`` is the (*)-identity, used to seed prefix products.
    """

    name: str
    add: Callable[[Array, Array], Array]
    mul: Callable[[Array, Array], Array]
    zero: float
    one: float

    def add_reduce(self, x: Array, axis: int) -> Array:
        if self.name == "real":
            return jnp.sum(x, axis=axis)
        if self.name == "maxplus":
            return jnp.max(x, axis=axis)
        if self.name == "minplus":
            return jnp.min(x, axis=axis)
        raise NotImplementedError(self.name)

    def matmul(self, a: Array, b: Array) -> Array:
        """Generalized matmul over the semiring: (..., m, k) x (..., k, n)."""
        if self.name == "real":
            return jnp.matmul(a, b)
        # (..., m, k, 1) (*) (..., 1, k, n) -> add-reduce over k
        prod = self.mul(a[..., :, :, None], b[..., None, :, :])
        return self.add_reduce(prod, axis=-2)

    def affine_apply(self, a: Array, b: Array, x: Array) -> Array:
        """x' = (a (*) x) (+) b, elementwise (diagonal transition)."""
        return self.add(self.mul(a, x), b)

    def affine_compose(self, a1: Array, b1: Array, a2: Array, b2: Array):
        """Compose elementwise affine maps: apply (a1,b1) first, then (a2,b2).

        (a2 (*) (a1 (*) x (+) b1)) (+) b2 = ((a2*a1) (*) x) (+) ((a2*b1)+b2)
        Distributivity of (*) over (+) — the semiring axiom — is exactly
        what makes this exact for max-plus/min-plus too.
        """
        return self.mul(a2, a1), self.add(self.mul(a2, b1), b2)


REAL = Semiring("real", add=jnp.add, mul=jnp.multiply, zero=0.0, one=1.0)
MAXPLUS = Semiring("maxplus", add=jnp.maximum, mul=jnp.add,
                   zero=-jnp.inf, one=0.0)
MINPLUS = Semiring("minplus", add=jnp.minimum, mul=jnp.add,
                   zero=jnp.inf, one=0.0)

SEMIRINGS = {s.name: s for s in (REAL, MAXPLUS, MINPLUS)}


def finite_zero(sr: Semiring, dtype) -> Array:
    """A finite stand-in for the (+)-identity, safe for int dtypes."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(sr.zero, dtype)
    info = jnp.iinfo(dtype)
    if sr.name == "maxplus":
        return jnp.asarray(info.min // 2, dtype)
    if sr.name == "minplus":
        return jnp.asarray(info.max // 2, dtype)
    return jnp.asarray(0, dtype)
