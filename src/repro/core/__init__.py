"""repro.core — Squire's contribution as composable JAX modules.

The dependency-decomposition engine (semiring / scan1d / wavefront) plus the
five paper kernels (chain, DTW, Smith-Waterman, radix sort, seeding).
"""

from repro.core.semiring import MAXPLUS, MINPLUS, REAL, SEMIRINGS, Semiring
from repro.core.scan1d import (affine_scan, affine_scan_associative,
                               affine_scan_chunked, affine_scan_sequential,
                               diag_rank1_scan)
from repro.core.wavefront import dp_tile_diagonal, pad_to_multiple, run_wavefront
from repro.core import align, chain, dtw, seeding, sort, spmv

__all__ = [
    "MAXPLUS", "MINPLUS", "REAL", "SEMIRINGS", "Semiring",
    "affine_scan", "affine_scan_associative", "affine_scan_chunked",
    "affine_scan_sequential", "diag_rank1_scan",
    "dp_tile_diagonal", "pad_to_multiple", "run_wavefront",
    "align", "chain", "dtw", "seeding", "sort", "spmv",
]
