"""Smith-Waterman local alignment (paper §III-B) on the wavefront engine.

Same left/up/diag dependency pattern as DTW (the paper treats them
together); (max,+) semiring with a zero floor and linear gap penalties:

    H[i,j] = max(0, H[i-1,j-1] + s(a_i, b_j),
                    H[i-1,j] - gap, H[i,j-1] - gap)

The alignment score is max_{i,j} H[i,j]. Tiles additionally carry a running
maximum so large alignments never materialize the full matrix.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import wavefront

Array = jnp.ndarray


class SWParams(NamedTuple):
    match: float = 2.0
    mismatch: float = -4.0
    gap: float = 4.0  # positive cost


def _cell(params: SWParams, diag, up, lft, av, bv):
    sub = jnp.where(av == bv, params.match, params.mismatch)
    h = jnp.maximum(diag + sub,
                    jnp.maximum(up - params.gap, lft - params.gap))
    return jnp.maximum(h, 0.0)


def sw_ref(a: Array, b: Array, params: SWParams = SWParams()) -> Array:
    """Oracle: sequential double scan; returns the full H matrix."""
    cell = functools.partial(_cell, params)
    m = b.shape[0]
    top = jnp.zeros((m,), jnp.float32)

    def row_step(prev_row, av):
        def col_step(carry, inp):
            lft, diag = carry
            up, bv = inp
            val = cell(diag, up, lft, av, bv)
            return (val, up), val
        _, row = jax.lax.scan(col_step, (jnp.float32(0), jnp.float32(0)),
                              (prev_row, b))
        return row, row

    _, mat = jax.lax.scan(row_step, top, a)
    return mat


def sw_score_ref(a: Array, b: Array, params: SWParams = SWParams()) -> Array:
    return jnp.max(sw_ref(a, b, params))


def _sw_tile_fn(params, top, left, corner, a, b):
    cell = functools.partial(_cell, params)
    return wavefront.dp_tile_diagonal(cell, top, left, corner, a, b)


def sw_tiled(a: Array, b: Array, params: SWParams = SWParams(),
             tile_r: int = 8, tile_c: int = 8, tile_fn=None):
    """Squire-style tiled wavefront SW; returns (H matrix, best score).

    Padding uses sentinel character 255, which mismatches every real base
    (0..3) and therefore cannot raise any score; the zero floor keeps the
    padded region at H=0-ish without affecting the true region (padded rows
    are below/right of all real cells, so no real cell depends on them).
    """
    n, m = a.shape[0], b.shape[0]
    ap = wavefront.pad_to_multiple(a, tile_r, 0, 255)
    bp = wavefront.pad_to_multiple(b, tile_c, 0, 255)
    npad, mpad = ap.shape[0], bp.shape[0]

    fn = tile_fn or functools.partial(_sw_tile_fn, params)
    mat, _, _, _ = wavefront.run_wavefront(
        fn, ap.astype(jnp.int32), bp.astype(jnp.int32),
        top0=jnp.zeros((mpad,), jnp.float32),
        left0=jnp.zeros((npad,), jnp.float32),
        corner0=jnp.float32(0.0),
        tile_r=tile_r, tile_c=tile_c, assemble=True)
    mat = mat[:n, :m]
    return mat, jnp.max(mat)


def sw_score(a: Array, b: Array, params: SWParams = SWParams(), **kw):
    return sw_tiled(a, b, params, **kw)[1]


def sw_end_position(mat: Array):
    """(i, j) of the best local alignment end."""
    flat = jnp.argmax(mat)
    return flat // mat.shape[1], flat % mat.shape[1]


# --------------------------------------------------------------------------
# Needleman-Wunsch (global alignment) — the paper names it alongside
# SW/DTW as the same left/up/diag dependency pattern (§V-C); it runs on
# the identical wavefront engine with different boundaries and no floor.
# --------------------------------------------------------------------------

def _nw_cell(params: SWParams, diag, up, lft, av, bv):
    sub = jnp.where(av == bv, params.match, params.mismatch)
    return jnp.maximum(diag + sub,
                       jnp.maximum(up - params.gap, lft - params.gap))


def nw_ref(a: Array, b: Array, params: SWParams = SWParams()) -> Array:
    """Oracle: sequential double scan; returns the full score matrix with
    linear gap boundaries (M[i, -1] = -(i+1)*gap, M[-1, j] = -(j+1)*gap)."""
    cell = functools.partial(_nw_cell, params)
    m = b.shape[0]
    top = -params.gap * jnp.arange(1, m + 1, dtype=jnp.float32)

    def row_step(carry, av_i):
        prev_row, left_val = carry
        corner = left_val + params.gap        # M[i-1, -1]

        def col_step(c, inp):
            lft, dg = c
            up, bv = inp
            val = cell(dg, up, lft, av_i, bv)
            return (val, up), val

        _, row = jax.lax.scan(col_step, (left_val, corner), (prev_row, b))
        return (row, left_val - params.gap), row

    left0 = jnp.float32(-params.gap)
    _, mat = jax.lax.scan(row_step, (top, left0), a)
    return mat


def nw_tiled(a: Array, b: Array, params: SWParams = SWParams(),
             tile_r: int = 8, tile_c: int = 8, tile_fn=None):
    """Tiled-wavefront global alignment; returns (matrix, score).

    Padding uses sentinels 254/255 (mutual mismatch), so padded cells can
    only extend through gap/mismatch penalties below every true cell —
    the true region is unaffected and the score is read at (n-1, m-1).
    """
    n, m = a.shape[0], b.shape[0]
    ap = wavefront.pad_to_multiple(a, tile_r, 0, 254)
    bp = wavefront.pad_to_multiple(b, tile_c, 0, 255)
    npad, mpad = ap.shape[0], bp.shape[0]

    cell = functools.partial(_nw_cell, params)
    fn = tile_fn or (lambda t, l, c, aa, bb:
                     wavefront.dp_tile_diagonal(cell, t, l, c, aa, bb))
    top0 = -params.gap * jnp.arange(1, mpad + 1, dtype=jnp.float32)
    left0 = -params.gap * jnp.arange(1, npad + 1, dtype=jnp.float32)
    mat, _, _, _ = wavefront.run_wavefront(
        fn, ap.astype(jnp.int32), bp.astype(jnp.int32),
        top0=top0, left0=left0, corner0=jnp.float32(0.0),
        tile_r=tile_r, tile_c=tile_c, assemble=True)
    mat = mat[:n, :m]
    return mat, mat[n - 1, m - 1]
