"""Chunked linear-attention recurrences — the paper's technique at LM scale.

The WKV6 (RWKV) and Mamba recurrences are diagonal-linear 1-D recurrences,
i.e. exactly the dependency pattern of the paper's chain kernel (DESIGN.md
§3.1). The Squire execution model maps onto them directly:

  * worker chunk   -> a C-step time chunk; all chunks' *intra*-chunk work is
                      dependency-free and dense (MXU matmuls),
  * global counter -> the chunk-boundary state handoff: a short sequential
                      scan over T/C boundary states instead of T steps,
  * loop fission   -> the readout y_t is split into an intra-chunk causal
                      matmul term and an inter-chunk `rq @ S_in` term.

Both functions compute the *outputs* y directly without materializing the
(T, dk, dv) state tape — only (T/C) boundary states are kept, which is what
makes 524k-token contexts feasible (the `long_500k` shape).

Numerics: computed in fp32. Per-step log-decay is clamped to >= -1
(w >= e^-1), so with chunk <= 64 every within-chunk exponent is bounded by
64 < log(fp32_max) ~ 88 and the rescaled-key trick is exact with no
overflow. RWKV6/Mamba trained decays live in (0.9, 1); the clamp is a
safety contract, not an approximation in practice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray

_MIN_LOGW = -1.0  # w >= e^-1; keeps all chunk exponents fp32-safe for C<=64


def wkv_chunked(r: Array, w: Array, k: Array, v: Array, u: Array | None,
                s0: Array | None = None, chunk: int = 64,
                variant: str = "tape", out_dtype=None):
    """RWKV6-style readout over the diagonal-linear recurrence.

        S_t = diag(w_t) S_{t-1} + k_t^T v_t
        y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

    Args:
      r, w, k: (B, T, dk). w is the multiplicative decay in (0, 1].
      v: (B, T, dv).
      u: (dk,) current-token bonus (None or zeros for pure linear attn).
      s0: (B, dk, dv) initial state (decode continuation) or None.
      chunk: the Squire worker granularity (<= 64, see module docstring).
      variant: 'tape' (default; two-phase vectorized form — fastest under
        autodiff, see EXPERIMENTS.md §Perf rwkv6 iter 2: the 'fused'
        single-scan form stacks fp32 residuals per chunk and LOSES) or
        'fused'.
      out_dtype: dtype of the emitted y tape (default fp32; the model
        passes bf16 — halves the dominant tape bytes, EXPERIMENTS.md
        §Perf rwkv6 iteration 2).

    Returns: (y: (B, T, dv) [out_dtype], s_final: (B, dk, dv) fp32).
    """
    if variant == "fused":
        return _wkv_chunked_fused(r, w, k, v, u, s0, chunk, out_dtype)
    assert chunk <= 64, "chunk > 64 breaks the fp32 exponent bound"
    b, t, dk = r.shape
    dv = v.shape[-1]
    f32 = lambda x: x.astype(jnp.float32)
    r, w, k, v = map(f32, (r, w, k, v))

    pad = (-t) % chunk
    if pad:
        z = jnp.zeros((b, pad, dk), jnp.float32)
        r = jnp.concatenate([r, z], 1)
        k = jnp.concatenate([k, z], 1)
        w = jnp.concatenate([w, jnp.ones((b, pad, dk), jnp.float32)], 1)
        v = jnp.concatenate([v, jnp.zeros((b, pad, dv), jnp.float32)], 1)
    tp = t + pad
    nc = tp // chunk

    rc = r.reshape(b, nc, chunk, dk)
    wc = w.reshape(b, nc, chunk, dk)
    kc = k.reshape(b, nc, chunk, dk)
    vc = v.reshape(b, nc, chunk, dv)

    logw = jnp.maximum(jnp.log(jnp.maximum(wc, 1e-38)), _MIN_LOGW)
    cum = jnp.cumsum(logw, axis=2)                     # cum_j = sum_{i<=j}
    cum_prev = cum - logw                              # decay start -> j-1
    d_full = jnp.exp(cum[:, :, -1])                    # (b, nc, dk)

    rq = rc * jnp.exp(cum_prev)                        # r_j decayed from start
    ks = kc * jnp.exp(-cum)                            # k_i advanced to start
    kd = kc * jnp.exp(cum[:, :, -1:, :] - cum)         # k_i decayed to end

    # intra-chunk causal readout: pairs (i < j) within the chunk
    att = jnp.einsum("bnjk,bnik->bnji", rq, ks)        # (b, nc, C, C)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    att = jnp.where(mask, att, 0.0)
    y_intra = jnp.einsum("bnji,bniv->bnjv", att, vc)

    if u is not None:
        bonus = jnp.einsum("bnjk,k,bnjk->bnj", rc, f32(u), kc)
        y_intra = y_intra + bonus[..., None] * vc

    # chunk summaries + boundary handoff (the global-counter scan)
    upd = jnp.einsum("bnik,bniv->bnkv", kd, vc)        # (b, nc, dk, dv)
    if s0 is None:
        s0 = jnp.zeros((b, dk, dv), jnp.float32)

    def boundary(s, du):
        d, uc = du
        s_next = d[:, :, None] * s + uc
        return s_next, s                               # emit incoming state

    s_final, s_in = jax.lax.scan(
        boundary, f32(s0),
        (d_full.transpose(1, 0, 2), upd.transpose(1, 0, 2, 3)))
    s_in = s_in.transpose(1, 0, 2, 3)                  # (b, nc, dk, dv)

    y = y_intra + jnp.einsum("bnjk,bnkv->bnjv", rq, s_in)
    y = y.reshape(b, tp, dv)[:, :t]
    if out_dtype is not None:
        y = y.astype(out_dtype)
    return y, s_final


def _wkv_chunked_fused(r: Array, w: Array, k: Array, v: Array,
                       u: Array | None, s0: Array | None, chunk: int,
                       out_dtype=None):
    """Single-scan WKV: the boundary handoff and the intra-chunk readout
    share one loop body, so no (nc, B, dk, dv) state tape, no transposed
    copies, and per-chunk decay math stays transient (§Perf rwkv6 iter 2).

    Identical math to the 'tape' variant; bytes drop ~2x at train_4k scale
    (measured in EXPERIMENTS.md §Perf).
    """
    assert chunk <= 64, "chunk > 64 breaks the fp32 exponent bound"
    b, t, dk = r.shape
    dv = v.shape[-1]
    out_dtype = out_dtype or jnp.float32

    pad = (-t) % chunk
    if pad:
        zk = jnp.zeros((b, pad, dk), r.dtype)
        r = jnp.concatenate([r, zk], 1)
        k = jnp.concatenate([k, jnp.zeros((b, pad, dk), k.dtype)], 1)
        w = jnp.concatenate([w, jnp.ones((b, pad, dk), w.dtype)], 1)
        v = jnp.concatenate([v, jnp.zeros((b, pad, dv), v.dtype)], 1)
    tp = t + pad
    nc = tp // chunk

    # scan layout (nc, b, C, d): one transpose of the compact input dtype
    def to_scan(x, d):
        return x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)

    xs = (to_scan(r, dk), to_scan(w, dk), to_scan(k, dk), to_scan(v, dv))
    s0 = jnp.zeros((b, dk, dv), jnp.float32) if s0 is None \
        else s0.astype(jnp.float32)
    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)
    uf = None if u is None else u.astype(jnp.float32)

    def body(s, x):
        rc, wc, kc, vc = (z.astype(jnp.float32) for z in x)  # (b, C, d)
        logw = jnp.maximum(jnp.log(jnp.maximum(wc, 1e-38)), _MIN_LOGW)
        cum = jnp.cumsum(logw, axis=1)                 # (b, C, dk)
        rq = rc * jnp.exp(cum - logw)                  # decayed from start
        ks = kc * jnp.exp(-cum)                        # advanced to start
        kd = kc * jnp.exp(cum[:, -1:, :] - cum)        # decayed to end

        att = jnp.einsum("bjk,bik->bji", rq, ks) * mask
        y = jnp.einsum("bji,biv->bjv", att, vc)
        if uf is not None:
            bonus = jnp.einsum("bjk,k,bjk->bj", rc, uf, kc)
            y = y + bonus[..., None] * vc
        y = y + jnp.einsum("bjk,bkv->bjv", rq, s)      # inter-chunk term
        upd = jnp.einsum("bik,biv->bkv", kd, vc)
        s = jnp.exp(cum[:, -1])[:, :, None] * s + upd
        return s, y.astype(out_dtype)

    s_final, ys = jax.lax.scan(body, s0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, tp, dv)[:, :t]
    return y, s_final


def wkv_ref(r, w, k, v, u, s0=None):
    """Sequential oracle for wkv_chunked (same clamp contract)."""
    b, t, dk = r.shape
    dv = v.shape[-1]
    f32 = lambda x: x.astype(jnp.float32)
    r, w, k, v = map(f32, (r, w, k, v))
    w = jnp.exp(jnp.maximum(jnp.log(jnp.maximum(w, 1e-38)), _MIN_LOGW))
    if s0 is None:
        s0 = jnp.zeros((b, dk, dv), jnp.float32)
    uu = jnp.zeros((dk,), jnp.float32) if u is None else f32(u)

    def one(rb, wb, kb, vb, s0b):
        def step(s, rwkv):
            rt, wt, kt, vt = rwkv
            kv = kt[:, None] * vt[None, :]
            yt = jnp.sum(rt[:, None] * (s + uu[:, None] * kv), axis=0)
            s = wt[:, None] * s + kv
            return s, yt
        s, y = jax.lax.scan(step, f32(s0b), (rb, wb, kb, vb))
        return y, s

    y, s = jax.vmap(one)(r, w, k, v, s0)
    return y, s


def mamba_chunked(x: Array, dt: Array, a: Array, b_in: Array, c_in: Array,
                  d_skip: Array, h0: Array | None = None, chunk: int = 64):
    """Mamba (S6) selective scan, chunk-parallel.

        h_t = exp(dt_t * A) (.) h_{t-1} + (dt_t * x_t) B_t     (d, n) state
        y_t = h_t C_t^T + D (.) x_t

    Args:
      x, dt: (B, T, d) input and positive step sizes.
      a: (d, n) negative state matrix (continuous-time A).
      b_in, c_in: (B, T, n) input/output projections.
      d_skip: (d,) skip connection.
      h0: (B, d, n) initial state or None.
      chunk: worker granularity.

    Returns: (y: (B, T, d) fp32, h_final: (B, d, n) fp32).

    The boundary handoff materializes only (T/C) states; within chunks the
    prefix is a rescaled cumsum (dependency-free across chunks — the same
    fission as wkv_chunked, with elementwise (d, n) channels instead of the
    rank-1 matmul form).
    """
    bsz, t, d = x.shape
    n = a.shape[-1]
    f32 = lambda z: z.astype(jnp.float32)
    x, dt, a, b_in, c_in, d_skip = map(f32, (x, dt, a, b_in, c_in, d_skip))

    pad = (-t) % chunk
    if pad:
        x = jnp.concatenate([x, jnp.zeros((bsz, pad, d), jnp.float32)], 1)
        dt = jnp.concatenate([dt, jnp.zeros((bsz, pad, d), jnp.float32)], 1)
        b_in = jnp.concatenate(
            [b_in, jnp.zeros((bsz, pad, n), jnp.float32)], 1)
        c_in = jnp.concatenate(
            [c_in, jnp.zeros((bsz, pad, n), jnp.float32)], 1)
    tp = t + pad
    nc = tp // chunk

    xc = x.reshape(bsz, nc, chunk, d)
    dtc = dt.reshape(bsz, nc, chunk, d)
    bc = b_in.reshape(bsz, nc, chunk, n)
    cc = c_in.reshape(bsz, nc, chunk, n)

    # log decay per step/(channel,state): dt * A  (clamped like wkv)
    la = jnp.maximum(dtc[..., :, None] * a[None, None, None], _MIN_LOGW)
    cum = jnp.cumsum(la, axis=2)                       # (b,nc,C,d,n)
    # input contribution u_i = dt_i x_i B_i (outer over n)
    u = (dtc * xc)[..., :, None] * bc[..., None, :]    # (b,nc,C,d,n)
    # within-chunk prefix: h_j = e^{cum_j} (h_in + sum_{i<=j} e^{-cum_i} u_i)
    acc = jnp.cumsum(jnp.exp(-cum) * u, axis=2)

    d_full = jnp.exp(cum[:, :, -1])                    # (b,nc,d,n)
    upd = d_full * acc[:, :, -1]                       # sum_i e^{cum_C-cum_i}u

    if h0 is None:
        h0 = jnp.zeros((bsz, d, n), jnp.float32)

    def boundary(h, du):
        dd, uc = du
        return dd * h + uc, h

    h_final, h_in = jax.lax.scan(
        boundary, f32(h0),
        (d_full.transpose(1, 0, 2, 3), upd.transpose(1, 0, 2, 3)))
    h_in = h_in.transpose(1, 0, 2, 3)                  # (b,nc,d,n)

    h = jnp.exp(cum) * (h_in[:, :, None] + acc)        # (b,nc,C,d,n)
    y = jnp.einsum("bnjds,bnjs->bnjd", h, cc)
    y = y + d_skip * xc
    y = y.reshape(bsz, tp, d)[:, :t]
    return y, h_final


def mamba_ref(x, dt, a, b_in, c_in, d_skip, h0=None):
    """Sequential oracle for mamba_chunked (same clamp contract)."""
    bsz, t, d = x.shape
    n = a.shape[-1]
    f32 = lambda z: z.astype(jnp.float32)
    x, dt, a, b_in, c_in, d_skip = map(f32, (x, dt, a, b_in, c_in, d_skip))
    if h0 is None:
        h0 = jnp.zeros((bsz, d, n), jnp.float32)

    def one(xb, dtb, bb, cb, h0b):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            la = jnp.maximum(dtt[:, None] * a, _MIN_LOGW)
            h = jnp.exp(la) * h + (dtt * xt)[:, None] * bt[None, :]
            yt = jnp.einsum("ds,s->d", h, ct) + d_skip * xt
            return h, yt
        h, y = jax.lax.scan(step, f32(h0b), (xb, dtb, bb, cb))
        return y, h

    y, h = jax.vmap(one)(x, dt, b_in, c_in, h0)
    return y, h


def wkv_decode_step(r, w, k, v, u, s):
    """Single-token WKV update (serving): r/w/k: (B, dk); v: (B, dv);
    s: (B, dk, dv). Returns (y: (B, dv), s_next)."""
    f32 = lambda z: z.astype(jnp.float32)
    r, w, k, v, s = map(f32, (r, w, k, v, s))
    w = jnp.exp(jnp.maximum(jnp.log(jnp.maximum(w, 1e-38)), _MIN_LOGW))
    kv = k[:, :, None] * v[:, None, :]
    uu = jnp.zeros_like(r[0]) if u is None else f32(u)
    y = jnp.einsum("bk,bkv->bv", r, s + uu[None, :, None] * kv)
    s_next = w[:, :, None] * s + kv
    return y, s_next


def mamba_decode_step(x, dt, a, b_in, c_in, d_skip, h):
    """Single-token Mamba update: x/dt: (B, d); b_in/c_in: (B, n);
    h: (B, d, n). Returns (y: (B, d), h_next)."""
    f32 = lambda z: z.astype(jnp.float32)
    x, dt, a, b_in, c_in, d_skip, h = map(
        f32, (x, dt, a, b_in, c_in, d_skip, h))
    la = jnp.maximum(dt[:, :, None] * a[None], _MIN_LOGW)
    h_next = jnp.exp(la) * h + (dt * x)[:, :, None] * b_in[:, None, :]
    y = jnp.einsum("bds,bs->bd", h_next, c_in) + d_skip * x
    return y, h_next
