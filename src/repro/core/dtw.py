"""Dynamic Time Warping (paper §III-C, Alg. 4) on the wavefront engine.

Cell recurrence (Eq. 2):  M[i,j] = |S[i]-R[j]| + min(M[i-1,j-1],
                                                     M[i-1,j], M[i,j-1])

Three implementations, all exact:
  * dtw_ref        — sequential double scan (the single-worker baseline).
  * dtw_diag       — full-matrix anti-diagonal vectorization (classic SIMD).
  * dtw_tiled      — Squire mapping: (tile_r x tile_c) VMEM tiles walked in
                     wavefront order; boundary vectors are the local-counter
                     handoffs. Tile inner loop is diagonal-vectorized.

Boundary convention: virtual row/col -1 hold +inf except corner (-1,-1)=0,
so M[0,0] = |S[0]-R[0]|.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import wavefront

Array = jnp.ndarray

_BIG = jnp.float32(jnp.finfo(jnp.float32).max / 4)


def _cell(diag, up, lft, av, bv):
    return jnp.abs(av - bv) + jnp.minimum(diag, jnp.minimum(up, lft))


def dtw_ref(s: Array, r: Array) -> Array:
    """Oracle: row-by-row scan with a sequential in-row scan. O(n*m) depth."""
    n, m = s.shape[0], r.shape[0]
    top = jnp.full((m,), _BIG, jnp.float32)

    def row_step(prev_row, carry_sc):
        av, corner_in = carry_sc

        def col_step(carry, inp):
            lft, diag = carry
            up, bv = inp
            val = _cell(diag, up, lft, av, bv)
            return (val, up), val

        (_, _), row = jax.lax.scan(
            col_step, (_BIG, corner_in), (prev_row, r))
        return row, row

    corners = jnp.concatenate([jnp.zeros((1,), jnp.float32),
                               jnp.full((n - 1,), _BIG, jnp.float32)])
    _, mat = jax.lax.scan(row_step, top, (s, corners))
    return mat


def dtw_diag(s: Array, r: Array) -> Array:
    """Anti-diagonal vectorized full matrix (fine-grain parallel, untiled)."""
    tile, _, _, _ = wavefront.dp_tile_diagonal(
        _cell,
        top=jnp.full((r.shape[0],), _BIG, jnp.float32),
        left=jnp.full((s.shape[0],), _BIG, jnp.float32),
        corner=jnp.float32(0.0), a=s, b=r)
    return tile


def _dtw_tile_fn(top, left, corner, a, b):
    return wavefront.dp_tile_diagonal(_cell, top, left, corner, a, b)


def dtw_tiled(s: Array, r: Array, tile_r: int = 8, tile_c: int = 8,
              tile_fn=None, assemble: bool = True):
    """Squire-style tiled wavefront DTW.

    Inputs are padded to tile multiples with +BIG samples, which keeps the
    padded region from contaminating the true distance (any path through a
    padded cell costs >= BIG). Returns (matrix (n,m) or None, distance).
    """
    n, m = s.shape[0], r.shape[0]
    sp = wavefront.pad_to_multiple(s.astype(jnp.float32), tile_r, 0, 1e18)
    rp = wavefront.pad_to_multiple(r.astype(jnp.float32), tile_c, 0, 1e18)
    npad, mpad = sp.shape[0], rp.shape[0]

    mat, bottom, right, _ = wavefront.run_wavefront(
        tile_fn or _dtw_tile_fn, sp, rp,
        top0=jnp.full((mpad,), _BIG, jnp.float32),
        left0=jnp.full((npad,), _BIG, jnp.float32),
        corner0=jnp.float32(0.0),
        tile_r=tile_r, tile_c=tile_c, assemble=assemble)

    if assemble:
        mat = mat[:n, :m]
        return mat, mat[n - 1, m - 1]
    # distance must be read from the unpadded corner; without assembly we
    # require exact tiling (callers pad inputs themselves).
    if npad == n and mpad == m:
        return None, bottom[m - 1]
    raise ValueError("assemble=False requires tile-aligned inputs")


def dtw_distance(s: Array, r: Array, **kw) -> Array:
    return dtw_tiled(s, r, **kw)[1]
