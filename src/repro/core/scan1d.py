"""1-D dependency-bound recurrence engine (the Squire global counter, in JAX).

The paper's 1-D pattern (chain kernel, Alg. 3): iteration ``i`` consumes
values produced by earlier iterations through a serialized handoff — in
Squire, workers publish ``f(i)`` by incrementing a hardware *global counter*
in order. Here the recurrence

    x_t = (a_t (*) x_{t-1}) (+) b_t        (elementwise over the state)

is executed in one of three modes:

* ``sequential`` — ``lax.scan``; the software-mutex baseline of Fig. 7.
* ``chunked``    — Squire-faithful: the timeline is split into W chunks
  ("workers"); each worker computes its local prefix solution independently
  (fine-grain parallel), and only the chunk-boundary states flow through a
  short sequential scan (the global counter handoff). Work is 2x but depth
  drops from T to T/W + W.
* ``associative`` — beyond-paper: ``lax.associative_scan`` over affine
  elements; O(log T) depth. The ordered-increment hardware dissolves into
  semiring associativity.

All three are exact (semiring distributivity), which property tests assert.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.semiring import REAL, Semiring, finite_zero

Array = jnp.ndarray


def _identity_pair(sr: Semiring, shape, dtype) -> Tuple[Array, Array]:
    one = jnp.full(shape, sr.one, dtype)
    zero = jnp.broadcast_to(finite_zero(sr, dtype), shape)
    return one, zero


def affine_scan_sequential(a: Array, b: Array, x0: Array,
                           sr: Semiring = REAL) -> Array:
    """Reference: plain lax.scan. Returns x_t for t = 1..T, shape = a.shape."""

    def step(x, ab):
        at, bt = ab
        x = sr.affine_apply(at, bt, x)
        return x, x

    _, xs = jax.lax.scan(step, x0, (a, b))
    return xs


def affine_scan_associative(a: Array, b: Array, x0: Array,
                            sr: Semiring = REAL) -> Array:
    """Parallel prefix over affine elements: depth O(log T)."""

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return sr.affine_compose(a1, b1, a2, b2)

    pa, pb = jax.lax.associative_scan(combine, (a, b), axis=0)
    # x_t = (prefix_a_t (*) x0) (+) prefix_b_t
    return sr.affine_apply(pa, pb, x0[None])


def affine_scan_chunked(a: Array, b: Array, x0: Array, sr: Semiring = REAL,
                        num_chunks: int = 8,
                        boundary_mode: str = "sequential") -> Array:
    """Squire-faithful chunked execution.

    Each of ``num_chunks`` workers owns a contiguous chunk. Phase 1 (parallel
    across workers, vmapped): local prefix affine maps. Phase 2 (the global-
    counter handoff): scan over the ``num_chunks`` boundary summaries. Phase 3
    (parallel): apply each worker's local prefixes to its incoming state.
    """
    t = a.shape[0]
    lc = -(-t // num_chunks)  # ceil
    pad = lc * num_chunks - t
    if pad:
        ia, ib = _identity_pair(sr, (pad,) + a.shape[1:], a.dtype)
        a = jnp.concatenate([a, ia], axis=0)
        b = jnp.concatenate([b, ib], axis=0)

    rest = a.shape[1:]
    ac = a.reshape((num_chunks, lc) + rest)
    bc = b.reshape((num_chunks, lc) + rest)

    def local_prefix(a_chunk, b_chunk):
        # prefix affine maps within a chunk, starting from identity
        def step(carry, ab):
            pa, pb = carry
            at, bt = ab
            pa, pb = sr.affine_compose(pa, pb, at, bt)
            return (pa, pb), (pa, pb)

        ident = _identity_pair(sr, rest, a_chunk.dtype)
        _, (pas, pbs) = jax.lax.scan(step, ident, (a_chunk, b_chunk))
        return pas, pbs

    pas, pbs = jax.vmap(local_prefix)(ac, bc)          # (W, lc, ...)
    sum_a, sum_b = pas[:, -1], pbs[:, -1]              # chunk summaries

    if boundary_mode == "associative":
        def combine(e1, e2):
            return sr.affine_compose(e1[0], e1[1], e2[0], e2[1])
        ca, cb = jax.lax.associative_scan(combine, (sum_a, sum_b), axis=0)
        starts = jnp.concatenate(
            [x0[None], sr.affine_apply(ca[:-1], cb[:-1], x0[None])], axis=0)
    else:
        def bstep(x, ab):
            x_next = sr.affine_apply(ab[0], ab[1], x)
            return x_next, x  # emit the *incoming* state of each chunk
        _, starts = jax.lax.scan(bstep, x0, (sum_a, sum_b))

    xs = sr.affine_apply(pas, pbs, starts[:, None])    # (W, lc, ...)
    xs = xs.reshape((num_chunks * lc,) + rest)
    return xs[:t]


def affine_scan(a: Array, b: Array, x0: Array, sr: Semiring = REAL,
                mode: str = "sequential", num_chunks: int = 8,
                boundary_mode: str = "sequential") -> Array:
    """Run the affine recurrence; all modes produce identical results."""
    if mode == "sequential":
        return affine_scan_sequential(a, b, x0, sr)
    if mode == "associative":
        return affine_scan_associative(a, b, x0, sr)
    if mode == "chunked":
        return affine_scan_chunked(a, b, x0, sr, num_chunks=num_chunks,
                                   boundary_mode=boundary_mode)
    raise ValueError(f"unknown scan1d mode: {mode!r}")


# ---------------------------------------------------------------------------
# Matrix-state recurrences (diagonal decay + rank-1 update): the SSM/RWKV
# workhorse. State S: (..., dk, dv);  S_t = diag(w_t) S_{t-1} + k_t^T v_t.
# This is the chain-kernel pattern at LM scale (DESIGN.md §3.1).
# ---------------------------------------------------------------------------

def diag_rank1_scan(w: Array, k: Array, v: Array, s0: Array,
                    mode: str = "chunked", chunk: int = 64):
    """Diagonal-linear matrix-state recurrence.

    Args:
      w: (T, dk) per-step decay (already exp'd; multiplicative).
      k: (T, dk), v: (T, dv) rank-1 update factors.
      s0: (dk, dv) initial state.
      mode: 'sequential' | 'chunked'. Chunked materializes states only at
        chunk boundaries and reconstructs within chunks with dense matmuls
        (MXU-friendly) — the Squire worker partitioning.

    Returns:
      y_states: (T, dk, dv) state after each step.
    """
    t, dk = w.shape
    dv = v.shape[-1]

    if mode == "sequential":
        def step(s, wkv):
            wt, kt, vt = wkv
            s = wt[:, None] * s + kt[:, None] * vt[None, :]
            return s, s
        _, states = jax.lax.scan(step, s0, (w, k, v))
        return states

    # chunked: within a chunk of length L, with incoming state S_in:
    #   S_j = D_j * S_in + sum_{i<=j} (D_j / D_i) k_i^T v_i,
    # where D_j = prod_{i<=j} diag(w_i). Compute with cumprods + matmuls.
    lc = chunk
    nch = -(-t // lc)
    pad = nch * lc - t
    if pad:
        w = jnp.concatenate([w, jnp.ones((pad, dk), w.dtype)], 0)
        k = jnp.concatenate([k, jnp.zeros((pad, dk), k.dtype)], 0)
        v = jnp.concatenate([v, jnp.zeros((pad, dv), v.dtype)], 0)

    wc = w.reshape(nch, lc, dk)
    kc = k.reshape(nch, lc, dk)
    vc = v.reshape(nch, lc, dv)

    logw = jnp.log(jnp.maximum(wc, 1e-38))
    cum = jnp.cumsum(logw, axis=1)                     # log D_j
    d_full = jnp.exp(cum[:, -1])                       # (nch, dk) chunk decay
    # chunk summary update: U_c = sum_i (D_L / D_i) k_i^T v_i
    scale = jnp.exp(cum[:, -1:, :] - cum)              # (nch, lc, dk)
    u = jnp.einsum("clk,clv->ckv", scale * kc, vc)     # (nch, dk, dv)

    def boundary(s, du):
        d, uc = du
        s_next = d[:, None] * s + uc
        return s_next, s  # incoming state per chunk
    _, s_in = jax.lax.scan(boundary, s0, (d_full, u))  # (nch, dk, dv)

    # within-chunk reconstruction (parallel across chunks):
    # S_j = exp(cum_j) * S_in + sum_{i<=j} exp(cum_j - cum_i) k_i v_i^T
    # realized with a causal (lc x lc) matmul over the k-dimension per dk —
    # to stay O(lc*dk*dv) we instead fold the decay into k and v:
    #   S_j = exp(cum_j)*S_in + exp(cum_j) * cumsum_i<=j[ (k_i/exp(cum_i)) v_i ]
    k_scaled = kc * jnp.exp(-cum)                      # (nch, lc, dk)
    outer = k_scaled[..., :, None] * vc[..., None, :]  # (nch, lc, dk, dv)
    acc = jnp.cumsum(outer, axis=1)                    # within-chunk prefix
    states = (jnp.exp(cum)[..., None] * (s_in[:, None] + acc))
    states = states.reshape(nch * lc, dk, dv)
    return states[:t]
