"""2-D tiled wavefront engine (the Squire *local counters*, in JAX).

Squire solves 2-D DP matrices (DTW, Smith-Waterman) by giving each worker a
block of columns; worker x hands the right boundary of each row to worker
x+1 through a per-worker hardware counter (Alg. 4, Fig. 5). The TPU-native
equivalent blocks the matrix into (tile_r x tile_c) VMEM tiles and walks
tiles in anti-diagonal wavefront order: all tiles on a diagonal are
dependency-free (fine-grain parallel); the boundary vectors that Squire
passed through the L2 + counters become explicit carries between tile calls.

The engine is generic over the tile function:

    tile_fn(top: (tc,), left: (tr,), corner: (), a: (tr,), b: (tc,))
        -> (tile: (tr, tc), bottom: (tc,), right: (tr,), corner_out: ())

where `a`/`b` are the per-row / per-column inputs of the tile (signal
samples, sequence characters, ...). The engine only schedules; DTW/SW
supply tile_fns (pure-jnp diagonal-vectorized, or the Pallas kernel).
"""

from __future__ import annotations

from typing import Callable, Tuple

import jax.numpy as jnp

Array = jnp.ndarray
TileFn = Callable[..., Tuple[Array, Array, Array, Array]]


def pad_to_multiple(x: Array, mult: int, axis: int, fill) -> Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def run_wavefront(tile_fn: TileFn, a: Array, b: Array, top0: Array,
                  left0: Array, corner0: Array, tile_r: int, tile_c: int,
                  assemble: bool = True):
    """Walk the (len(a) x len(b)) DP matrix in tile-wavefront order.

    Args:
      tile_fn: see module docstring.
      a: (n,) row inputs; b: (m,) column inputs. Must be multiples of the
        tile sizes (use pad_to_multiple with a neutral fill).
      top0: (m,) DP boundary row above the matrix (row -1).
      left0: (n,) DP boundary column left of the matrix (col -1).
      corner0: scalar DP value at (-1, -1).
      assemble: if True return the full (n, m) matrix; otherwise only the
        final bottom row / right column (enough for DTW distance or SW max
        when tracked inside tile_fn).

    Returns:
      (matrix_or_None, bottom_row: (m,), right_col: (n,), corner: ()).

    Tiles on the same anti-diagonal have no mutual dependencies — XLA sees
    them as independent ops (the parallelism Squire's workers exploit). The
    Python loop only fixes the partial order, exactly like the counters.
    """
    n, m = a.shape[-1], b.shape[-1]
    if n % tile_r or m % tile_c:
        raise ValueError(f"inputs ({n},{m}) not multiples of tile "
                         f"({tile_r},{tile_c}); pad first")
    nr, nc = n // tile_r, m // tile_c
    lead = a.shape[:-1]          # () here; (B,) via run_wavefront_batched

    # boundary state, indexed by tile coordinates
    bottoms = [[None] * nc for _ in range(nr)]   # (tc,) below tile (r,c)
    rights = [[None] * nc for _ in range(nr)]    # (tr,) right of tile (r,c)
    corners = [[None] * nc for _ in range(nr)]   # () at tile (r,c) low-right
    tiles = [[None] * nc for _ in range(nr)] if assemble else None

    a_t = a.reshape(lead + (nr, tile_r))
    b_t = b.reshape(lead + (nc, tile_c))
    top_t = top0.reshape(lead + (nc, tile_c))
    left_t = left0.reshape(lead + (nr, tile_r))

    for d in range(nr + nc - 1):                 # wavefront order
        r_lo, r_hi = max(0, d - nc + 1), min(nr - 1, d)
        for r in range(r_lo, r_hi + 1):          # independent tiles of diag d
            c = d - r
            top = bottoms[r - 1][c] if r > 0 else top_t[..., c, :]
            left = rights[r][c - 1] if c > 0 else left_t[..., r, :]
            if r > 0 and c > 0:
                corner = corners[r - 1][c - 1]
            elif r > 0:
                corner = left_t[..., r - 1, -1]  # == M[r*tr-1, -1]
            elif c > 0:
                corner = top_t[..., c - 1, -1]   # == M[-1, c*tc-1]
            else:
                corner = corner0
            tile, bottom, right, corner_out = tile_fn(
                top, left, corner, a_t[..., r, :], b_t[..., c, :])
            bottoms[r][c], rights[r][c] = bottom, right
            corners[r][c] = corner_out
            if assemble:
                tiles[r][c] = tile

    bottom_row = jnp.concatenate([bottoms[nr - 1][c] for c in range(nc)],
                                 axis=-1)
    right_col = jnp.concatenate([rights[r][nc - 1] for r in range(nr)],
                                axis=-1)
    final_corner = corners[nr - 1][nc - 1]
    if assemble:
        matrix = jnp.concatenate(
            [jnp.concatenate(row, axis=-1) for row in tiles], axis=-2)
        return matrix, bottom_row, right_col, final_corner
    return None, bottom_row, right_col, final_corner


def run_wavefront_batched(tile_fn_b: TileFn, a: Array, b: Array, top0: Array,
                          left0: Array, corner0: Array, tile_r: int,
                          tile_c: int, assemble: bool = True):
    """Batched run_wavefront: every operand carries a leading batch axis.

    This is the runtime's "accelerator pool" schedule: one wavefront walk
    serves a whole batch of same-shape DP problems, each tile call landing
    on the batched tile function (``jax.vmap`` of a TileFn — the pool of
    per-core Squire workers attacking one tile each). Host scheduling cost
    is paid once per tile instead of once per tile *per request*.

    Args:
      tile_fn_b: batched tile function taking top (B, tc), left (B, tr),
        corner (B,), a (B, tr), b (B, tc) and returning (tile (B, tr, tc),
        bottom (B, tc), right (B, tr), corner_out (B,)).
      a: (B, n) row inputs; b: (B, m) column inputs (tile multiples).
      top0: (B, m); left0: (B, n); corner0: (B,).

    Returns (matrix (B, n, m) or None, bottom (B, m), right (B, n),
    corner (B,)); identical per-row to run_wavefront on that row.
    """
    if a.ndim != 2 or b.shape[0] != a.shape[0]:
        raise ValueError(f"expected (B, n)/(B, m) inputs, got "
                         f"{a.shape} / {b.shape}")
    return run_wavefront(tile_fn_b, a, b, top0, left0, corner0,
                         tile_r, tile_c, assemble=assemble)


def dp_tile_diagonal(cell_update, top: Array, left: Array, corner: Array,
                     a: Array, b: Array):
    """Generic diagonal-vectorized DP tile (the fine-grain parallel inner
    loop). Computes M[i,j] = cell_update(diag, up, lft, a[i], b[j]) for a
    (tr x tc) tile given boundaries, sweeping 2*max(tr,tc)-ish anti-diagonals
    with all cells of a diagonal updated in one vector op.

    Works for DTW (min-plus) and SW (max-plus with floor) via cell_update.
    Pure jnp; the Pallas kernel mirrors this structure inside VMEM.
    """
    tr, tc = a.shape[0], b.shape[0]
    dtype = top.dtype

    # M padded with one boundary row/col: shape (tr+1, tc+1)
    mat = jnp.zeros((tr + 1, tc + 1), dtype)
    mat = mat.at[0, 0].set(corner)
    mat = mat.at[0, 1:].set(top)
    mat = mat.at[1:, 0].set(left)

    rows = jnp.arange(1, tr + 1)
    # Unrolled anti-diagonal sweep: diagonal k holds cells (i, k - i).
    for k in range(2, tr + tc + 1):
        cols = k - rows                          # (tr,)
        valid = (cols >= 1) & (cols <= tc)
        cc = jnp.clip(cols, 1, tc)
        diag = mat[rows - 1, cc - 1]
        up = mat[rows - 1, cc]
        lft = mat[rows, cc - 1]
        av = a[rows - 1]
        bv = b[cc - 1]
        new = cell_update(diag, up, lft, av, bv)
        keep = mat[rows, cc]
        mat = mat.at[rows, cc].set(jnp.where(valid, new, keep))

    tile = mat[1:, 1:]
    return tile, tile[-1, :], tile[:, -1], tile[-1, -1]
