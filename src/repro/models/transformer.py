"""Composable decoder covering all 10 assigned architectures.

The stack is a *period pattern* of LayerSpecs (configs.base) repeated
``num_periods`` times. The runtime `lax.scan`s over periods with stacked
per-period parameters, so HLO size and compile time are flat in depth
(16-60 layer models share one block program), and XLA's latency-hiding
scheduler can overlap the per-period FSDP all-gathers with compute.

Modes:
  * train    — full-sequence forward, returns (logits, aux_loss).
  * prefill  — full-sequence forward, returns (last-token logits, caches).
  * decode   — single-token step with caches, returns (logits, caches).

Caches are a dict keyed by pattern position (``p0``...), each leaf stacked
over periods — attention layers hold KVCache ring buffers, RWKV/Mamba
layers hold O(1) recurrent state (which is why `long_500k` decode is flat
in context length for the SSM/hybrid archs; DESIGN.md §3.1).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention, layers as L, moe as moe_lib, ssm
from repro.sharding import shard_act

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# sub-config adapters
# ---------------------------------------------------------------------------

def _attn_cfg(cfg: ModelConfig, spec: LayerSpec) -> attention.AttnConfig:
    return attention.AttnConfig(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
        bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, rope_theta=spec.rope_theta,
        window=spec.window, kv_block=cfg.kv_block)


def _moe_cfg(cfg: ModelConfig) -> moe_lib.MoEConfig:
    return moe_lib.MoEConfig(
        d_model=cfg.d_model, d_ff=cfg.moe_d_ff or cfg.d_ff,
        num_experts=cfg.num_experts,
        experts_per_token=cfg.experts_per_token,
        capacity_factor=cfg.capacity_factor, act=cfg.act)


def _rwkv_cfg(cfg: ModelConfig) -> ssm.RWKVConfig:
    return ssm.RWKVConfig(d_model=cfg.d_model, head_dim=cfg.rwkv_head_dim,
                          scan_chunk=cfg.scan_chunk)


def _mamba_cfg(cfg: ModelConfig) -> ssm.MambaConfig:
    return ssm.MambaConfig(d_model=cfg.d_model, d_state=cfg.ssm_state,
                           expand=cfg.ssm_expand,
                           scan_chunk=cfg.scan_chunk)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, spec: LayerSpec):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": L.init_rmsnorm(d),
                         "norm2": L.init_rmsnorm(d)}
    if spec.mixer == "attn":
        p["attn"] = attention.init_attention(k1, _attn_cfg(cfg, spec))
    elif spec.mixer == "rwkv":
        p["rwkv"] = ssm.init_rwkv_time_mix(k1, _rwkv_cfg(cfg))
    elif spec.mixer == "mamba":
        p["mamba"] = ssm.init_mamba(k1, _mamba_cfg(cfg))
    else:
        raise ValueError(spec.mixer)
    if spec.mlp == "dense":
        p["mlp"] = L.init_mlp(k2, d, cfg.d_ff)
    elif spec.mlp == "moe":
        p["moe"] = moe_lib.init_moe(k2, _moe_cfg(cfg))
    elif spec.mlp == "rwkv_ffn":
        p["rwkv_ffn"] = ssm.init_rwkv_channel_mix(k2, d, cfg.d_ff)
    else:
        raise ValueError(spec.mlp)
    return p


def init_model(key, cfg: ModelConfig):
    keys = jax.random.split(key, len(cfg.pattern) + 3)
    params: Dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        params["embed"] = L.init_embedding(keys[-1], cfg.vocab, cfg.d_model)
    blocks = {}
    for i, spec in enumerate(cfg.pattern):
        pk = jax.random.split(keys[i], cfg.num_periods)
        blocks[f"p{i}"] = jax.vmap(
            lambda k, s=spec: _init_layer(k, cfg, s))(pk)
    params["blocks"] = blocks
    params["final_norm"] = L.init_rmsnorm(cfg.d_model)
    if not (cfg.tie_embeddings and cfg.input_mode == "tokens"):
        params["unembed"] = L.init_unembed(keys[-2], cfg.vocab, cfg.d_model)
    return params


def param_count(params) -> int:
    return int(sum(x.size for x in jax.tree_util.tree_leaves(params)))


def active_param_count(params, cfg: ModelConfig) -> int:
    """6*N_active*D accounting for MoE: experts count at k/E of their size."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        p = "/".join(str(k) for k in path)
        n = leaf.size
        if "expert_" in p and cfg.num_experts:
            n = n * cfg.experts_per_token // cfg.num_experts
        total += n
    return int(total)


# ---------------------------------------------------------------------------
# one layer
# ---------------------------------------------------------------------------

def _apply_layer(p, cfg: ModelConfig, spec: LayerSpec, x: Array,
                 positions: Array, cache, mode: str,
                 pos_scalar: Optional[Array], cache_slots: int):
    new_cache: Optional[Dict[str, Any]] = None
    h = L.rmsnorm(p["norm1"], x)
    if spec.mixer == "attn":
        acfg = _attn_cfg(cfg, spec)
        if mode == "decode":
            y, kvc = attention.attention(p["attn"], acfg, h, positions,
                                         cache=cache["attn"],
                                         position_scalar=pos_scalar)
            new_cache = {"attn": kvc}
        else:
            slots = None
            if mode == "prefill":
                slots = min(cache_slots, spec.window) if spec.window \
                    else cache_slots
            y, kvc = attention.attention(p["attn"], acfg, h, positions,
                                         make_cache_slots=slots)
            if kvc is not None:
                new_cache = {"attn": kvc}
    elif spec.mixer == "rwkv":
        rcfg = _rwkv_cfg(cfg)
        if mode == "decode":
            if h.shape[1] == 1:
                y, st = ssm.rwkv_time_mix_decode(p["rwkv"], rcfg, h,
                                                 cache["rwkv"])
            else:       # chunked prefill: state-carried chunk-parallel scan
                y, st = ssm.rwkv_time_mix(p["rwkv"], rcfg, h,
                                          cache["rwkv"])
        else:
            y, st = ssm.rwkv_time_mix(p["rwkv"], rcfg, h, None)
        new_cache = {"rwkv": st}
    elif spec.mixer == "mamba":
        mcfg = _mamba_cfg(cfg)
        if mode == "decode":
            if h.shape[1] == 1:
                y, st = ssm.mamba_block_decode(p["mamba"], mcfg, h,
                                               cache["mamba"])
            else:       # chunked prefill continuation
                y, st = ssm.mamba_block(p["mamba"], mcfg, h,
                                        cache["mamba"])
        else:
            y, st = ssm.mamba_block(p["mamba"], mcfg, h, None)
        new_cache = {"mamba": st}
    else:
        raise ValueError(spec.mixer)
    x = x + y
    x = shard_act(x, "batch", "seq", None)

    h2 = L.rmsnorm(p["norm2"], x)
    aux = jnp.zeros((), jnp.float32)
    if spec.mlp == "dense":
        y2 = L.mlp(p["mlp"], h2, act=cfg.act)
    elif spec.mlp == "moe":
        y2, aux = moe_lib.moe(p["moe"], _moe_cfg(cfg), h2)
    elif spec.mlp == "rwkv_ffn":
        x_prev = cache.get("ffn_x") if (cache and mode == "decode") else None
        y2, ffn_x = ssm.rwkv_channel_mix(p["rwkv_ffn"], h2, x_prev)
        if new_cache is None:
            new_cache = {}
        new_cache["ffn_x"] = ffn_x
    else:
        raise ValueError(spec.mlp)
    x = x + y2
    x = shard_act(x, "batch", "seq", None)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def apply_model(params, cfg: ModelConfig, *, tokens: Optional[Array] = None,
                embeds: Optional[Array] = None,
                positions: Optional[Array] = None,
                caches=None, mode: str = "train",
                pos_scalar: Optional[Array] = None,
                cache_slots: int = 0):
    """Returns (logits, aux_loss, new_caches_or_None)."""
    assert mode in ("train", "prefill", "decode"), mode
    dt = cfg.dtype
    if embeds is not None:
        x = embeds.astype(dt)
    else:
        x = L.embed(params["embed"], tokens, dt)
        if cfg.scale_embed:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    b, s, _ = x.shape
    x = shard_act(x, "batch", "seq", None)

    if positions is None:
        if mode == "decode":
            # pos_scalar: scalar (shared clock) or (B,) vector — per-row
            # clocks for continuous batching; x may carry a chunk (S >= 1)
            # of consecutive tokens starting at that position per row.
            p0 = jnp.asarray(pos_scalar, jnp.int32)
            if p0.ndim == 0:
                p0 = jnp.broadcast_to(p0, (b,))
            positions = p0[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
        else:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                         (b, s))

    pattern = cfg.pattern
    want_caches = mode != "train"

    def body(xc, xs_):
        bp, cache_p = xs_
        aux_t = jnp.zeros((), jnp.float32)
        new_caches = {}
        for i, spec in enumerate(pattern):
            ci = cache_p[f"p{i}"] if cache_p is not None else None
            xc, nc, aux = _apply_layer(bp[f"p{i}"], cfg, spec, xc, positions,
                                       ci, mode, pos_scalar, cache_slots)
            if want_caches:
                new_caches[f"p{i}"] = nc
            aux_t = aux_t + aux
        ys = {"aux": aux_t}
        if want_caches:
            ys["caches"] = new_caches
        return xc, ys

    if cfg.remat and mode == "train":
        if cfg.remat_policy == "dots":
            # recompute elementwise chains, keep MXU dot outputs — trades
            # residency for recompute bytes (§Perf rwkv6 iteration 4)
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots)
        else:
            body = jax.checkpoint(body)

    x, ys = jax.lax.scan(body, x, (params["blocks"], caches))
    aux_loss = jnp.sum(ys["aux"])
    new_caches = ys.get("caches")

    x = L.rmsnorm(params["final_norm"], x)
    if mode == "prefill":
        x = x[:, -1:]       # prefill callers only consume the last logits
    # decode chunks (s > 1) keep ALL s positions: the unembed over the
    # full chunk is what speculative verify and prompt scoring consume —
    # the compute already happened, this only sizes the output.
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        table = params["embed"]["table"]
    else:
        table = params["unembed"]["table"]
    logits = L.logits({"table": table}, x)
    logits = shard_act(logits, "batch", "seq", "vocab")
    return logits, aux_loss, new_caches


# ---------------------------------------------------------------------------
# decode-cache allocation (static shapes for serving / dry-run)
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, slots: int,
                per_slot_pos: bool = False,
                paged_global_attn: bool = False,
                paged_window_attn: bool = False):
    """Zero caches for decode: dict p<i> -> stacked-over-periods leaves.

    ``per_slot_pos=True`` allocates the per-row KV position layout
    (pos: (periods, batch, slots)) so every batch row carries its own
    decode clock — the layout serve.slots.SlotManager pools. With it,
    EVERY cache leaf has the batch axis at position 1, which is what
    makes slot gather/scatter a single-axis indexing op.

    ``paged_global_attn=True`` leaves ``{"attn": None}`` for layers whose
    slot axis would span the full ``slots`` (global attention, or a
    window >= slots): those leaves live in a block pool owned by the
    paged slot backing (serve.paging) instead of being reserved per slot.

    ``paged_window_attn=True`` additionally drops the dense ring leaves
    of sliding-window layers with ``window < slots``: their rings page
    through a ring-mode PageTable group (blocks map lazily while a
    request ramps up to ``window`` written positions, then the full ring
    stays resident), so Pareto-short requests stop reserving a dense
    ``window``-row slab they never fill. SSM state is O(1) per slot —
    it cannot strand pool memory and always stays dense.
    """
    np_, d = cfg.num_periods, cfg.d_model
    caches = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.mixer == "attn":
            sl = min(slots, spec.window) if spec.window else slots
            if (paged_global_attn and sl == slots) or \
                    (paged_window_attn and sl < slots):
                caches[f"p{i}"] = {"attn": None}
                continue
            pos = (jnp.full((np_, batch, sl), -1, jnp.int32)
                   if per_slot_pos else jnp.full((np_, sl), -1, jnp.int32))
            caches[f"p{i}"] = {"attn": attention.KVCache(
                k=jnp.zeros((np_, batch, sl, cfg.num_kv_heads,
                             cfg.head_dim), jnp.bfloat16),
                v=jnp.zeros((np_, batch, sl, cfg.num_kv_heads,
                             cfg.head_dim), jnp.bfloat16),
                pos=pos)}
        elif spec.mixer == "rwkv":
            h, hd = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
            caches[f"p{i}"] = {
                "rwkv": {"s": jnp.zeros((np_, batch, h, hd, hd),
                                        jnp.float32),
                         "x_prev": jnp.zeros((np_, batch, d), jnp.float32)},
                "ffn_x": jnp.zeros((np_, batch, d), jnp.float32)}
        elif spec.mixer == "mamba":
            mcfg = _mamba_cfg(cfg)
            caches[f"p{i}"] = {"mamba": {
                "conv": jnp.zeros((np_, batch, mcfg.conv_kernel - 1,
                                   mcfg.d_inner), jnp.float32),
                "h": jnp.zeros((np_, batch, mcfg.d_inner, mcfg.d_state),
                               jnp.float32)}}
    return caches


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def lm_loss(logits: Array, labels: Array, mask: Optional[Array] = None,
            z_weight: float = 1e-4) -> Tuple[Array, Dict[str, Array]]:
    """Masked CE (fp32) + z-loss. labels: (B, S) int32; mask 1.0 = keep."""
    logits = logits.astype(jnp.float32)
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum((logz - ll) * mask) / denom
    zl = z_weight * jnp.sum(jnp.square(logz) * mask) / denom
    metrics = {"ce": ce, "z_loss": zl}
    return ce + zl, metrics
