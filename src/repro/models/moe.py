"""Mixture-of-Experts layer: token-choice top-k routing with capacity.

Two dispatch paths:

* ``_moe_gspmd`` — global-capacity scatter/gather dispatch, sharding left
  to GSPMD. Correct everywhere (single device, any mesh), but at
  production scale XLA materializes and ALL-REDUCES the replicated
  (E, C_global, D) buffer — measured 820 GB/device/step of all-reduce on
  olmoe train_4k (EXPERIMENTS.md §Perf, MoE baseline).

* ``_moe_shard_map`` — GShard-style local-group dispatch (§Perf MoE
  iteration 1): each device routes its own tokens into a local-capacity
  (E, c_loc, D) buffer, exchanges token-shards for expert-shards with ONE
  ``all_to_all`` along the expert ('model') axis, runs its local experts,
  and reverses the exchange. Collective traffic per layer becomes
  tokens_loc x k x D — ~100x less than the scatter path. Capacity
  semantics become per-group (standard GShard local groups; documented
  divergence from the global-capacity oracle when capacity is tight).

The shard_map path activates when the configured mesh has a 'model' axis
that divides num_experts; otherwise the GSPMD path runs (single-device
tests, reduced smoke configs).

Routing is dependency-free (noted in DESIGN.md §3.3: the paper's technique
does not apply to dispatch itself); the expert FFNs are dense MXU work.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                    # jax >= 0.6 re-exports at top level
    _shard_map = jax.shard_map
except AttributeError:                  # 0.4.x experimental home
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.models import layers as L
from repro.sharding import current_mesh, logical, shard_act
from repro.sharding.partition import param_spec

Array = jnp.ndarray


class MoEConfig(NamedTuple):
    d_model: int
    d_ff: int
    num_experts: int
    experts_per_token: int
    capacity_factor: float = 1.25
    act: str = "swiglu"
    router_aux_weight: float = 0.01


def init_moe(key, cfg: MoEConfig):
    kr, kg, ku, kd = jax.random.split(key, 4)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    return {
        "router": L.truncated_normal(kr, (d, e), 0.02),
        "expert_gate": L.he_init(kg, (e, d, f), d),
        "expert_up": L.he_init(ku, (e, d, f), d),
        "expert_down": L.he_init(kd, (e, f, d), f),
    }


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(n_tokens * cfg.experts_per_token * cfg.capacity_factor
            / cfg.num_experts)
    return max(4, -(-c // 4) * 4)


def moe(params, cfg: MoEConfig, x: Array):
    """x: (B, S, D) -> (y: (B, S, D), aux_loss: scalar fp32)."""
    mesh = current_mesh()
    if mesh is not None and not mesh.empty and "model" in mesh.axis_names:
        from repro.sharding import resolve_axes
        m = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        spec = resolve_axes(x.shape, ("batch", "seq", None))
        sharded_over_model = any(
            "model" in ((e,) if isinstance(e, str) else tuple(e))
            for e in spec if e is not None)
        # shard_map pays off only when tokens actually shard over 'model';
        # decode (seq=1) would run the exchange 'model'-times redundantly
        # (measured 5x regression on jamba decode — §Perf MoE notes).
        if m > 1 and cfg.num_experts % m == 0 and sharded_over_model:
            return _moe_shard_map(params, cfg, x, mesh, m)
    return _moe_gspmd(params, cfg, x)


def _local_dispatch(xt: Array, top_e: Array, top_p: Array, e: int, c: int):
    """Scatter n local tokens into an (E, c, D) buffer; returns the buffer
    plus (flat_e, flat_pos, keep, flat_p) for the combine."""
    n, d = xt.shape
    k = top_e.shape[-1]
    flat_e = top_e.reshape(-1)
    flat_p = top_p.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < c
    flat_pos = jnp.minimum(flat_pos, c - 1)
    tok_idx = jnp.repeat(jnp.arange(n), k)
    src = jnp.where(keep[:, None], xt[tok_idx], 0).astype(xt.dtype)
    xb = jnp.zeros((e, c, d), xt.dtype).at[flat_e, flat_pos].add(src)
    return xb, (flat_e, flat_pos, keep, flat_p)


def _router(params, cfg: MoEConfig, xt: Array):
    logits = (xt.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    return probs, top_p, top_e


def _aux_loss(cfg: MoEConfig, probs: Array, top_e: Array) -> Array:
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(
        top_e, cfg.num_experts, dtype=jnp.float32), axis=1), axis=0)
    return cfg.router_aux_weight * cfg.num_experts * jnp.sum(me * ce)


def _moe_shard_map(params, cfg: MoEConfig, x: Array, mesh, m: int):
    """GShard local-group dispatch with an all-to-all expert exchange."""
    from repro.sharding import resolve_axes

    e, k = cfg.num_experts, cfg.experts_per_token
    e_loc = e // m
    axes = mesh.axis_names

    # divisibility-aware specs (decode has seq=1, long-context has batch=1;
    # whatever cannot shard arrives replicated and is simply not gathered)
    x_spec = resolve_axes(x.shape, ("batch", "seq", None))
    router_spec = param_spec("router", params["router"].shape)
    wg_spec = param_spec("expert_gate", params["expert_gate"].shape)

    used: set = set()
    for entry in x_spec:
        if entry is not None:
            used.update((entry,) if isinstance(entry, str) else entry)
    unused_axes = tuple(a for a in axes if a not in used)

    def _gather_axes(val, spec, dim):
        """all_gather `val` along every mesh axis spec[dim] names."""
        entry = spec[dim] if dim < len(spec) else None
        if entry is None:
            return val
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        for name in names:
            val = jax.lax.all_gather(val, name, axis=dim, tiled=True)
        return val

    def body(router_w, wg, wu, wd, xs):
        bl, sl, d = xs.shape
        n = bl * sl
        c = capacity(n, cfg)
        xt = xs.reshape(n, d)

        router_w = _gather_axes(_gather_axes(router_w, router_spec, 0),
                                router_spec, 1)
        probs, top_p, top_e = _router({"router": router_w}, cfg, xt)
        aux = _aux_loss(cfg, probs, top_e)
        aux = jax.lax.pmean(aux, axes)

        xb, (flat_e, flat_pos, keep, flat_p) = _local_dispatch(
            xt, top_e, top_p, e, c)

        # token-shards -> expert-shards: one all_to_all over 'model'
        xe = xb.reshape(m, e_loc, c, d)
        xe = jax.lax.all_to_all(xe, "model", split_axis=0, concat_axis=0,
                                tiled=False)          # (m, e_loc, c, d)
        xe = xe.transpose(1, 0, 2, 3).reshape(e_loc, m * c, d)

        # local experts: FSDP all-gather of the weight shards (dim 1)
        def gather_w(wshard):
            return _gather_axes(wshard, wg_spec, 1)

        dt = xs.dtype
        wg_f = gather_w(wg).astype(dt)
        wu_f = gather_w(wu).astype(dt)
        # wd shards dim1 = d_ff over 'data' per param_spec positional rules
        wd_f = gather_w(wd).astype(dt)
        h = jnp.einsum("ecd,edf->ecf", xe, wg_f)
        h = jax.nn.silu(h) if cfg.act == "swiglu" else jax.nn.gelu(h)
        h = h * jnp.einsum("ecd,edf->ecf", xe, wu_f)
        ye = jnp.einsum("ecf,efd->ecd", h, wd_f)      # (e_loc, m*c, d)

        # reverse exchange: expert-shards -> token-shards
        ye = ye.reshape(e_loc, m, c, d).transpose(1, 0, 2, 3)
        ye = jax.lax.all_to_all(ye, "model", split_axis=0, concat_axis=0,
                                tiled=False)
        yb = ye.reshape(e, c, d)

        gathered = yb[flat_e, flat_pos]
        weighted = gathered * (flat_p * keep)[:, None].astype(dt)
        y = jnp.sum(weighted.reshape(n, k, d), axis=1)
        y = y.reshape(bl, sl, d)
        if unused_axes:
            # mesh axes x could not shard over (decode: seq=1; batch=1)
            # hold identical token copies: the pmean is an identity that
            # makes the replication explicit for shard_map's out check.
            y = jax.lax.pmean(y, unused_axes)
        return y, aux

    wrapped = _shard_map(
        body, mesh=mesh,
        in_specs=(router_spec, wg_spec, wg_spec, wg_spec, x_spec),
        out_specs=(x_spec, P()))
    y, aux = wrapped(params["router"], params["expert_gate"],
                     params["expert_up"], params["expert_down"], x)
    return y, aux[()] if aux.ndim else aux


def _moe_gspmd(params, cfg: MoEConfig, x: Array):
    """Global-capacity scatter dispatch; sharding left to GSPMD."""
    b, s, d = x.shape
    n = b * s
    k = cfg.experts_per_token
    e = cfg.num_experts
    c = capacity(n, cfg)
    dt = x.dtype

    xt = x.reshape(n, d)
    router_logits = (xt.astype(jnp.float32)
                     @ params["router"].astype(jnp.float32))      # (N, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                        # (N, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)        # renorm

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0)
    aux = cfg.router_aux_weight * e * jnp.sum(me * ce)

    # --- capacity-bounded dispatch -------------------------------------
    flat_e = top_e.reshape(-1)                                    # (N*k,)
    flat_p = top_p.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)           # (N*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                     # rank
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < c
    flat_pos = jnp.minimum(flat_pos, c - 1)

    tok_idx = jnp.repeat(jnp.arange(n), k)                        # (N*k,)
    xb = jnp.zeros((e, c, d), dt)
    src = jnp.where(keep[:, None], xt[tok_idx], 0).astype(dt)
    xb = xb.at[flat_e, flat_pos].add(src)                         # dispatch
    xb = shard_act(xb, "experts", "expert_capacity", None)

    # --- expert FFNs (batched over the expert axis) ---------------------
    wg = params["expert_gate"].astype(dt)
    wu = params["expert_up"].astype(dt)
    wd = params["expert_down"].astype(dt)
    h = jnp.einsum("ecd,edf->ecf", xb, wg)
    h = jax.nn.silu(h) if cfg.act == "swiglu" else jax.nn.gelu(h)
    h = h * jnp.einsum("ecd,edf->ecf", xb, wu)
    yb = jnp.einsum("ecf,efd->ecd", h, wd)                        # (E, C, D)
    yb = shard_act(yb, "experts", "expert_capacity", None)

    # --- combine ---------------------------------------------------------
    gathered = yb[flat_e, flat_pos]                               # (N*k, D)
    weighted = gathered * (flat_p * keep)[:, None].astype(dt)
    y = jnp.sum(weighted.reshape(n, k, d), axis=1)
    return y.reshape(b, s, d), aux
