"""RWKV6 (Finch) and Mamba blocks built on core.linear_attn.

Both are the LM-scale instances of the paper's 1-D dependency-bound pattern
(DESIGN.md §3.1): training/prefill runs the chunk-parallel path
(`wkv_chunked` / `mamba_chunked` — Squire's worker partitioning), decode
runs the O(1)-state single-step path. The recurrent state *is* the cache:
a 524k context costs the same per token as a 1k context (`long_500k`).

RWKV6 here implements the structural essentials of Finch: static token-
shift mixing vectors plus the headline *data-dependent decay* (a low-rank
MLP modulating w per token/channel), multi-head (dk = dv = 64) WKV with the
current-token bonus `u`, per-head groupnorm, and the squared-ReLU channel
mix. Mamba follows mamba-1: in/gate projections, depthwise causal conv,
selective (dt, B, C) projections, diagonal state update.
"""

from __future__ import annotations

import functools

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import linear_attn as la
from repro.models import layers as L
from repro.sharding import shard_act

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# RWKV6 time mix
# ---------------------------------------------------------------------------

class RWKVConfig(NamedTuple):
    d_model: int
    head_dim: int = 64
    decay_lora: int = 64
    scan_chunk: int = 64

    @property
    def num_heads(self) -> int:
        return self.d_model // self.head_dim


def init_rwkv_time_mix(key, cfg: RWKVConfig):
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    ramp = jnp.arange(d, dtype=jnp.float32) / d
    p = {
        # token-shift mixing coefficients (static lerp weights)
        "mu_r": 0.5 * (1 + ramp), "mu_k": 0.7 * (1 + ramp) / 2,
        "mu_v": 0.7 * (1 + ramp) / 2, "mu_w": 0.6 * (1 + ramp) / 2,
        "mu_g": 0.5 * (1 + ramp),
        "wr": L.he_init(ks[0], (d, d), d),
        "wk": L.he_init(ks[1], (d, d), d),
        "wv": L.he_init(ks[2], (d, d), d),
        "wg": L.he_init(ks[3], (d, d), d),
        "wo": L.he_init(ks[4], (d, d), d),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x A) B))
        "w0": -6.0 + 5.0 * ramp,                         # decay base
        "w_lora_a": L.truncated_normal(ks[5], (d, cfg.decay_lora), 0.02),
        "w_lora_b": jnp.zeros((cfg.decay_lora, d), jnp.float32),
        "u": L.truncated_normal(ks[6], (h, hd), 0.5),    # bonus
        "ln_x": L.init_groupnorm(d),                     # per-head norm
    }
    return p


def _token_shift(x: Array, x_prev: Optional[Array]) -> Array:
    """shifted[t] = x[t-1]; slot -1 comes from the decode state (or zeros)."""
    if x_prev is None:
        x_prev = jnp.zeros_like(x[:, :1])
    else:
        x_prev = x_prev[:, None] if x_prev.ndim == 2 else x_prev
    return jnp.concatenate([x_prev.astype(x.dtype), x[:, :-1]], axis=1)


def rwkv_time_mix(params, cfg: RWKVConfig, x: Array,
                  state: Optional[dict] = None, chunk: Optional[int] = None):
    """x: (B, S, D). state (decode/prefill-continuation) holds
    {"s": (B, H, hd, hd) fp32, "x_prev": (B, D)}. Returns (y, new_state).
    """
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    dt = x.dtype
    x_prev = state["x_prev"] if state is not None else None
    xs = _token_shift(x, x_prev)

    def mix(mu):
        return x + (xs - x) * mu.astype(dt)

    r = mix(params["mu_r"]) @ params["wr"].astype(dt)
    k = mix(params["mu_k"]) @ params["wk"].astype(dt)
    v = mix(params["mu_v"]) @ params["wv"].astype(dt)
    g = jax.nn.silu(mix(params["mu_g"]) @ params["wg"].astype(dt))
    # data-dependent decay (the Finch feature)
    xw = mix(params["mu_w"]).astype(jnp.float32)
    dd = jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"]
    w = jnp.exp(-jnp.exp(params["w0"] + dd))             # (B, S, D) in (0,1)

    # Layout choice (§Perf rwkv6 iterations 3/5, measured both ways):
    #  * fold (b*h) when b % n_devices == 0 — each device owns whole batch
    #    rows; the flat layout lets XLA fuse the chunked scan best
    #    (train_4k: collective 1421 -> 811 ms).
    #  * otherwise keep heads a REAL axis and vmap the scan over them —
    #    the misaligned fold makes GSPMD all-gather full fp32 tensors
    #    (prefill_32k with b=32: 689 GB/device, 30x regression).
    from repro.sharding import current_mesh
    mesh = current_mesh()
    n_dev = 1 if mesh is None or mesh.empty else mesh.devices.size
    use_fold = (b % max(n_dev, 1)) == 0
    s0 = state["s"] if state is not None else None       # (b, h, hd, hd)

    def to_heads(z):
        return z.reshape(b, s, h, hd).transpose(0, 2, 1, 3)

    if use_fold:
        def fold(z):
            return to_heads(z).reshape(b * h, s, hd)

        rf, wf, kf, vf = map(fold, (r, w, k, v))
        shard_fold = lambda z: shard_act(z, "ssm_fold", None, None)
        rf, wf, kf, vf = map(shard_fold, (rf, wf, kf, vf))
        s0f = s0.reshape(b * h, hd, hd) if s0 is not None else None
        yf, s_fin = la.wkv_chunked(rf, wf, kf, vf, None, s0f,
                                   chunk=chunk or cfg.scan_chunk,
                                   out_dtype=dt)
        yf = shard_fold(yf)
        uf = jnp.broadcast_to(params["u"][None], (b, h, hd))             .reshape(b * h, hd)
        bonus = jnp.einsum("btk,bk,btk->bt", rf.astype(jnp.float32),
                           uf, kf.astype(jnp.float32))
        yf = yf + bonus[..., None] * vf.astype(jnp.float32)
        yf = yf.reshape(b, h, s, hd)
        s_fin = s_fin.reshape(b, h, hd, hd)
    else:
        # misaligned fold: leave layout to GSPMD (no constraint) — measured
        # better than both the constrained fold (30x gathers) and a
        # vmap-over-heads form (2x) on prefill_32k / multi-pod trains.
        def fold(z):
            return to_heads(z).reshape(b * h, s, hd)

        rf, wf, kf, vf = map(fold, (r, w, k, v))
        s0f = s0.reshape(b * h, hd, hd) if s0 is not None else None
        yf, s_fin = la.wkv_chunked(rf, wf, kf, vf, None, s0f,
                                   chunk=chunk or cfg.scan_chunk,
                                   out_dtype=dt)
        uf = jnp.broadcast_to(params["u"][None], (b, h, hd)) \
            .reshape(b * h, hd)
        bonus = jnp.einsum("btk,bk,btk->bt", rf.astype(jnp.float32),
                           uf, kf.astype(jnp.float32))
        yf = yf + bonus[..., None] * vf.astype(jnp.float32)
        yf = yf.reshape(b, h, s, hd)
        s_fin = s_fin.reshape(b, h, hd, hd)

    y = yf.transpose(0, 2, 1, 3).reshape(b, s, d)
    y = shard_act(y, "batch", "seq", None)
    y = L.groupnorm(params["ln_x"], y.astype(dt), groups=h)
    y = (y * g) @ params["wo"].astype(dt)
    new_state = {"s": s_fin,
                 "x_prev": x[:, -1].astype(jnp.float32)}
    return y, new_state


def rwkv_time_mix_decode(params, cfg: RWKVConfig, x: Array, state: dict):
    """Single-token decode: x (B, 1, D). O(1) in context length."""
    b, _, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    dt = x.dtype
    xs = state["x_prev"][:, None].astype(dt)

    def mix(mu):
        return x + (xs - x) * mu.astype(dt)

    r = (mix(params["mu_r"]) @ params["wr"].astype(dt))[:, 0]
    k = (mix(params["mu_k"]) @ params["wk"].astype(dt))[:, 0]
    v = (mix(params["mu_v"]) @ params["wv"].astype(dt))[:, 0]
    g = jax.nn.silu(mix(params["mu_g"]) @ params["wg"].astype(dt))[:, 0]
    xw = mix(params["mu_w"]).astype(jnp.float32)[:, 0]
    dd = jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"]
    w = jnp.exp(-jnp.exp(params["w0"] + dd))             # (B, D)

    fold = lambda z: z.reshape(b * h, hd)
    s0 = state["s"].reshape(b * h, hd, hd)
    yf, s_fin = la.wkv_decode_step(fold(r), fold(w), fold(k), fold(v),
                                   None, s0)
    uf = jnp.broadcast_to(params["u"][None], (b, h, hd)).reshape(b * h, hd)
    bonus = jnp.einsum("bk,bk,bk->b", fold(r).astype(jnp.float32), uf,
                       fold(k).astype(jnp.float32))
    yf = yf + bonus[:, None] * fold(v).astype(jnp.float32)

    y = yf.reshape(b, h * hd)[:, None, :]
    y = L.groupnorm(params["ln_x"], y.astype(dt), groups=h)
    y = (y * g[:, None]) @ params["wo"].astype(dt)
    new_state = {"s": s_fin.reshape(b, h, hd, hd),
                 "x_prev": x[:, -1].astype(jnp.float32)}
    return y, new_state


def init_rwkv_state(batch: int, cfg: RWKVConfig):
    h, hd = cfg.num_heads, cfg.head_dim
    return {"s": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "x_prev": jnp.zeros((batch, cfg.d_model), jnp.float32)}


# ---------------------------------------------------------------------------
# RWKV channel mix (the arch's FFN; uses token shift too)
# ---------------------------------------------------------------------------

def init_rwkv_channel_mix(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    ramp = jnp.arange(d_model, dtype=jnp.float32) / d_model
    return {
        "mu_k": 0.5 * (1 + ramp), "mu_r": 0.5 * (1 + ramp),
        "wk": L.he_init(k1, (d_model, d_ff), d_model),
        "wv": L.he_init(k2, (d_ff, d_model), d_ff),
        "wr": L.he_init(k3, (d_model, d_model), d_model),
    }


def rwkv_channel_mix(params, x: Array, x_prev: Optional[Array] = None):
    """Squared-ReLU channel mix. Returns (y, x_last) for the decode shift."""
    dt = x.dtype
    xs = _token_shift(x, x_prev)
    xk = x + (xs - x) * params["mu_k"].astype(dt)
    xr = x + (xs - x) * params["mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(xk @ params["wk"].astype(dt)))
    y = jax.nn.sigmoid(xr @ params["wr"].astype(dt)) * \
        (kk @ params["wv"].astype(dt))
    return y, x[:, -1].astype(jnp.float32)


# ---------------------------------------------------------------------------
# Mamba (S6) block
# ---------------------------------------------------------------------------

class MambaConfig(NamedTuple):
    d_model: int
    d_state: int = 16
    expand: int = 2
    conv_kernel: int = 4
    scan_chunk: int = 64

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, -(-self.d_model // 16))


def init_mamba(key, cfg: MambaConfig):
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real init for A; dt bias init for softplus ~ [1e-3, 1e-1]
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
    dt_init = jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32)
                      * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    inv_softplus = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        "w_in": L.he_init(ks[0], (d, 2 * di), d),
        "conv_w": L.truncated_normal(ks[1], (cfg.conv_kernel, di), 0.2),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "w_x": L.he_init(ks[2], (di, r + 2 * n), di),
        "w_dt": L.he_init(ks[3], (r, di), r),
        "dt_bias": inv_softplus,
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": L.he_init(ks[5], (di, d), di),
    }


def _causal_conv(x: Array, w: Array, b: Array,
                 conv_state: Optional[Array] = None):
    """Depthwise causal conv along time. x: (B, S, di); w: (K, di).

    Returns (y: (B, S, di), new_conv_state: (B, K-1, di))."""
    kk = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], kk - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(kk))
    new_state = xp[:, -(kk - 1):].astype(jnp.float32)
    return y + b.astype(x.dtype), new_state


def mamba_block(params, cfg: MambaConfig, x: Array,
                state: Optional[dict] = None, chunk: Optional[int] = None):
    """x: (B, S, D). state = {"conv": (B, K-1, di), "h": (B, di, n)}.
    Returns (y (B, S, D), new_state)."""
    b, s, d = x.shape
    di, n, r = cfg.d_inner, cfg.d_state, cfg.dt_rank
    dt_ = x.dtype

    xz = x @ params["w_in"].astype(dt_)
    xi, z = xz[..., :di], xz[..., di:]
    conv_state = state["conv"] if state is not None else None
    xi, new_conv = _causal_conv(xi, params["conv_w"], params["conv_b"],
                                conv_state)
    xi = jax.nn.silu(xi)
    xi = shard_act(xi, "batch", "seq", "ssm_channels")

    proj = xi @ params["w_x"].astype(dt_)
    dt_low, b_in, c_in = proj[..., :r], proj[..., r:r + n], proj[..., r + n:]
    dt = jax.nn.softplus(dt_low.astype(jnp.float32) @ params["w_dt"]
                         + params["dt_bias"])
    a = -jnp.exp(params["a_log"])

    h0 = state["h"] if state is not None else None
    y, h_fin = la.mamba_chunked(xi, dt, a, b_in, c_in, params["d_skip"],
                                h0, chunk=chunk or cfg.scan_chunk)
    y = (y.astype(dt_) * jax.nn.silu(z)) @ params["w_out"].astype(dt_)
    new_state = {"conv": new_conv, "h": h_fin}
    return y, new_state


def mamba_block_decode(params, cfg: MambaConfig, x: Array, state: dict):
    """Single-token decode: x (B, 1, D)."""
    b, _, d = x.shape
    di, n, r = cfg.d_inner, cfg.d_state, cfg.dt_rank
    dt_ = x.dtype

    xz = (x @ params["w_in"].astype(dt_))[:, 0]
    xi, z = xz[..., :di], xz[..., di:]
    # conv ring update
    conv = state["conv"]                                  # (B, K-1, di)
    window = jnp.concatenate([conv.astype(dt_), xi[:, None]], axis=1)
    y = jnp.einsum("bkd,kd->bd", window, params["conv_w"].astype(dt_)) \
        + params["conv_b"].astype(dt_)
    new_conv = window[:, 1:].astype(jnp.float32)
    xi = jax.nn.silu(y)

    proj = xi @ params["w_x"].astype(dt_)
    dt_low, b_in, c_in = proj[..., :r], proj[..., r:r + n], proj[..., r + n:]
    dt = jax.nn.softplus(dt_low.astype(jnp.float32) @ params["w_dt"]
                         + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    yd, h = la.mamba_decode_step(xi, dt, a, b_in, c_in, params["d_skip"],
                                 state["h"])
    out = (yd.astype(dt_) * jax.nn.silu(z)) @ params["w_out"].astype(dt_)
    return out[:, None], {"conv": new_conv, "h": h}


def init_mamba_state(batch: int, cfg: MambaConfig):
    return {"conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner),
                              jnp.float32),
            "h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32)}
