"""GQA/MQA attention with blockwise online-softmax (flash-style) and
sliding-window + ring-buffer KV caches.

The blockwise pass is the attention instance of the paper's decomposition
(DESIGN.md §3.1): per-block score/AV work is dependency-free (MXU), while
the softmax normalizer is a tiny serial carry (running max + denominator)
— the same fission-plus-carry structure as the chain kernel. It is also
what keeps 32k prefill from materializing S^2 score matrices.

Ring-buffer local caches (gemma3 sliding-window layers) exploit that online
softmax is order-invariant: cache slots carry absolute positions, so a
rotating buffer needs no reordering before attending.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding import shard_act

Array = jnp.ndarray
NEG_INF = -1e30


class AttnConfig(NamedTuple):
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    bias: bool = False          # qwen-style QKV bias
    qk_norm: bool = False       # gemma3-style per-head RMS on q/k
    rope_theta: float = 1e4
    window: int = 0             # 0 = global; >0 sliding window
    kv_block: int = 512


def init_attention(key, cfg: AttnConfig):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, g, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": L.he_init(kq, (d, h * hd), d),
        "wk": L.he_init(kk, (d, g * hd), d),
        "wv": L.he_init(kv, (d, g * hd), d),
        "wo": L.he_init(ko, (h * hd, d), h * hd),
    }
    if cfg.bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((g * hd,), jnp.float32)
        p["bv"] = jnp.zeros((g * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = L.init_rmsnorm(hd)
        p["k_norm"] = L.init_rmsnorm(hd)
    return p


def blockwise_attention(q: Array, k: Array, v: Array, q_pos: Array,
                        kv_pos: Array, window: int = 0,
                        kv_block: int = 512) -> Array:
    """Online-softmax attention.

    q: (B, Sq, H, hd);  k/v: (B, Skv, KV, hd);  q_pos: (B, Sq) absolute
    positions; kv_pos: (B, Skv) absolute slot positions (-1 = empty slot).
    Causal + optional sliding window masking by *absolute position*, which
    makes ring buffers and padded caches free.
    """
    b, sq, h, hd = q.shape
    skv, kv_heads = k.shape[1], k.shape[2]
    grp = h // kv_heads
    scale = hd ** -0.5
    # MXU input dtype: bf16 for bf16 models (halves score traffic), but a
    # model running in fp32 must get fp32 scores — MoE routing sits on
    # near-ties that bf16 score noise (~1e-3) flips between the cached
    # decode path and the full forward (olmoe divergence, ROADMAP item).
    mxu_dt = jnp.bfloat16 if q.dtype == jnp.bfloat16 else jnp.float32

    blk = min(kv_block, skv)
    pad = (-skv) % blk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    nb = k.shape[1] // blk

    qr = (q.reshape(b, sq, kv_heads, grp, hd)
           .transpose(0, 2, 3, 1, 4)                    # (B, KV, G, Sq, hd)
           .astype(jnp.float32) * scale)
    kb = k.reshape(b, nb, blk, kv_heads, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nb, blk, kv_heads, hd).transpose(1, 0, 3, 2, 4)
    pb = kv_pos.reshape(b, nb, blk).transpose(1, 0, 2)  # (nb, B, blk)

    m0 = jnp.full((b, kv_heads, grp, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv_heads, grp, sq), jnp.float32)
    a0 = jnp.zeros((b, kv_heads, grp, sq, hd), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, posb = xs                           # (B,KV,blk,hd), (B,blk)
        # bf16 MXU inputs with fp32 accumulation (flash-attention numerics;
        # §Perf MoE-cell iteration 2 — halves the dominant score traffic)
        s = jnp.einsum("bkgsh,bkth->bkgst",
                       qr.astype(mxu_dt), kblk.astype(mxu_dt),
                       preferred_element_type=jnp.float32)  # (B,KV,G,Sq,blk)
        ok = (posb[:, None, None, None, :] <=
              q_pos[:, None, None, :, None])            # causal
        ok &= posb[:, None, None, None, :] >= 0         # empty slots
        if window > 0:
            ok &= (q_pos[:, None, None, :, None] -
                   posb[:, None, None, None, :]) < window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # clamp: rows with nothing visible yet keep m at NEG_INF
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(ok, p, 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)          # denominator in fp32
        # p stays fp32: casting it bf16 adds a same-size tensor without
        # removing one (measured — §Perf gemma3 iteration 2b), and fp32 p
        # keeps block-size invariance exact.
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,bkth->bkgsh", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def banded_attention(q: Array, k: Array, v: Array, q_pos: Array,
                     window: int) -> Array:
    """Exact sliding-window attention via block-banding (§Perf gemma3).

    For a causal window w, a query in sequence-block i (block size w) can
    only see keys in blocks i-1 and i. Attending to that 2w-key band is
    exact — and unlike the full blockwise path it neither gathers the
    whole KV sequence across the mesh nor scores masked-out blocks:
    score bytes drop Skv/(2w)-fold and the KV all-gather becomes a
    one-block halo exchange (collective-permute).

    q: (B, S, H, hd); k/v: (B, S, KV, hd); q_pos: (B, S) absolute
    positions (consecutive per row). S must be a multiple of w after
    padding (handled here).
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    grp = h // kvh
    scale = hd ** -0.5
    wb = window

    pad = (-s) % wb
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    sp = s + pad
    nb = sp // wb

    qb = q.reshape(b, nb, wb, h, hd)
    kb = k.reshape(b, nb, wb, kvh, hd)
    vb = v.reshape(b, nb, wb, kvh, hd)
    pb = q_pos.reshape(b, nb, wb)

    # band = previous block ++ own block (2w keys)
    shift = lambda z: jnp.concatenate(
        [jnp.zeros_like(z[:, :1]), z[:, :-1]], axis=1)
    k_band = jnp.concatenate([shift(kb), kb], axis=2)   # (b, nb, 2w, kv, hd)
    v_band = jnp.concatenate([shift(vb), vb], axis=2)
    p_band = jnp.concatenate(
        [jnp.full_like(pb[:, :1], -1), pb[:, :-1]], axis=1)
    p_band = jnp.concatenate([p_band, pb], axis=2)      # (b, nb, 2w)

    mxu_dt = jnp.bfloat16 if q.dtype == jnp.bfloat16 else jnp.float32
    qg = (qb.reshape(b, nb, wb, kvh, grp, hd).astype(mxu_dt))
    sc = jnp.einsum("bnqkgh,bntkh->bnkgqt", qg,
                    k_band.astype(mxu_dt),
                    preferred_element_type=jnp.float32) * scale
    ok = (p_band[:, :, None, None, None, :] <=
          pb[:, :, None, None, :, None])                # causal
    ok &= p_band[:, :, None, None, None, :] >= 0        # padding / block 0
    ok &= (pb[:, :, None, None, :, None] -
           p_band[:, :, None, None, None, :]) < window
    sc = jnp.where(ok, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    p = jnp.where(ok, p, 0.0)
    out = jnp.einsum("bnkgqt,bntkh->bnqkgh", p,
                     v_band.astype(jnp.float32))
    out = out.reshape(b, sp, h, hd)[:, :s]
    return out.astype(q.dtype)


class KVCache(NamedTuple):
    """Static-shape decode cache. `pos`: absolute position per slot
    (-1 empty). Local layers allocate `window` slots (ring buffer).

    Two position layouts:
      * shared  — ``pos: (S,)``: every batch row decodes at the same
        position (the classic synchronous-batch serve path).
      * per-row — ``pos: (B, S)``: each row carries its own clock, which
        is what continuous batching needs (serve.scheduler slots decode
        at different depths in one fused step).
    """
    k: Array      # (B, S, KV, hd)
    v: Array      # (B, S, KV, hd)
    pos: Array    # (S,) int32, or (B, S) int32 per-row


def make_cache(batch: int, slots: int, kv_heads: int, head_dim: int,
               dtype=jnp.bfloat16, per_row_pos: bool = False) -> KVCache:
    pos = (jnp.full((batch, slots), -1, jnp.int32) if per_row_pos
           else jnp.full((slots,), -1, jnp.int32))
    return KVCache(
        k=jnp.zeros((batch, slots, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, slots, kv_heads, head_dim), dtype),
        pos=pos)


def make_paged_cache(num_blocks: int, block_size: int, kv_heads: int,
                     head_dim: int, dtype=jnp.bfloat16,
                     periods: int = 1) -> KVCache:
    """Flat physical block-pool cache: rows = (num_blocks + 1) * block_size
    — one TRASH block appended past the pool as the gather/scatter sink
    for unmapped page-table entries (serve.paging). Backs global-attention
    KV and (ring-mode page tables) sliding-window rings alike: the view
    length lives in the page table, not here."""
    rows = (num_blocks + 1) * block_size
    return KVCache(
        k=jnp.zeros((periods, rows, kv_heads, head_dim), dtype),
        v=jnp.zeros((periods, rows, kv_heads, head_dim), dtype),
        pos=jnp.full((periods, rows), -1, jnp.int32))


def paged_live_rows(flat: KVCache, block_size: int) -> int:
    """Rows of ``flat`` backing real (non-trash) blocks. The trash
    sentinel is the LAST block of the flat pool, so the live prefix is a
    static shape fact — which lets the fused paged steps recover each
    page-table group's trash floor without threading per-group statics."""
    return flat.k.shape[1] - block_size


def paged_view(flat: KVCache, rows: Array, live_rows: int) -> KVCache:
    """Gather a per-slot contiguous KVCache view through a page table —
    the gather-before-attend step of the paged layout.

    flat: physical pool, k/v (P, R, KV, hd), pos (P, R); rows: (B, V)
    flat physical row per view position (PageTable.rows()); live_rows =
    num_blocks * block_size — rows at or past it are trash. Trash view
    positions read as the empty-slot encoding (k=v=0, pos=-1), which is
    bit-identical to the freshly-zeroed rows of a contiguous slot, so
    attending over the view reproduces the contiguous path exactly.

    The same gather IS the paged ring view: for a sliding-window layer V
    is the ring length ``min(window, cache_slots)`` and ``rows`` comes
    from a ring-mode PageTable, so ``cache_update``'s ``pos % V`` ring
    addressing and the absolute-position window mask resolve through the
    view bit-identically to the dense ring leaf (during ramp-up, the
    not-yet-mapped tail of the ring reads as empty slots — exactly what
    a dense ring holds there).
    """
    ok = rows < live_rows                                   # (B, V)
    k = jnp.where(ok[None, :, :, None, None],
                  jnp.take(flat.k, rows, axis=1), 0)
    v = jnp.where(ok[None, :, :, None, None],
                  jnp.take(flat.v, rows, axis=1), 0)
    pos = jnp.where(ok[None], jnp.take(flat.pos, rows, axis=1), -1)
    return KVCache(k=k, v=v, pos=pos)


def paged_writeback(flat: KVCache, view: KVCache, rows: Array) -> KVCache:
    """Scatter an updated per-slot view back into the physical pool.

    A mapped physical row has at most ONE writer per step, so the
    scatter is deterministic. That used to follow from blocks being
    uniquely mapped; with copy-on-write prefix sharing a block may be
    mapped read-shared under MANY slots (refcount > 1), and the
    guarantee instead comes from the scheduler: a shared block is never
    inside any slot's write span — the first write into one is preceded
    by a CoW copy onto a fresh private block (serve/slots.py ensure()).
    Writes for unmapped view positions
    (including whole dead slots) land in the trash block, which is never
    read unmasked. Ring writeback is the same scatter: a ring write at
    ``pos % V`` dirties exactly one view position, whose block the
    scheduler mapped before the step (ramp-up) or which is resident
    (steady state).
    """
    return KVCache(
        k=flat.k.at[:, rows].set(view.k.astype(flat.k.dtype)),
        v=flat.v.at[:, rows].set(view.v.astype(flat.v.dtype)),
        pos=flat.pos.at[:, rows].set(view.pos.astype(jnp.int32)))


def _shard_cache(c: KVCache) -> KVCache:
    return KVCache(
        k=shard_act(c.k, "cache_batch", "cache_seq", "cache_kv_heads",
                    "cache_head_dim"),
        v=shard_act(c.v, "cache_batch", "cache_seq", "cache_kv_heads",
                    "cache_head_dim"),
        pos=c.pos)


def cache_update(cache: KVCache, k_new: Array, v_new: Array,
                 position: Array) -> KVCache:
    """Insert new entries. Ring addressing: slot = pos % slots.

    ``position`` scalar: legacy single-step path (Sq=1, shared clock).
    ``position`` vector (B,): per-row path — k_new/v_new carry a chunk of
    Sq >= 1 consecutive tokens per row starting at ``position[b]``
    (Sq == 1 is plain per-slot decode; Sq > 1 is chunked prefill).
    Requires the per-row ``pos: (B, S)`` cache layout.
    """
    slots = cache.k.shape[1]
    if position.ndim == 0:
        slot = position % slots
        k = jax.lax.dynamic_update_slice(
            cache.k, k_new.astype(cache.k.dtype), (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache.v, v_new.astype(cache.v.dtype), (0, slot, 0, 0))
        pos = jax.lax.dynamic_update_slice(
            cache.pos, position[None].astype(jnp.int32), (slot,))
        return _shard_cache(KVCache(k, v, pos))

    assert cache.pos.ndim == 2, \
        "vector positions need the per-row pos=(B, S) cache layout"
    b, sq = k_new.shape[0], k_new.shape[1]
    # chunk longer than the ring: only the last `slots` tokens survive —
    # drop the rest up front so the scatter never writes a slot twice
    # (duplicate scatter indices with different values are unordered).
    if sq > slots:
        k_new, v_new = k_new[:, -slots:], v_new[:, -slots:]
        position = position + (sq - slots)
        sq = slots
    pos_mat = (position[:, None]
               + jnp.arange(sq, dtype=jnp.int32)[None, :])   # (B, Sq)
    slot = pos_mat % slots
    bidx = jnp.arange(b)[:, None]
    k = cache.k.at[bidx, slot].set(k_new.astype(cache.k.dtype))
    v = cache.v.at[bidx, slot].set(v_new.astype(cache.v.dtype))
    pos = cache.pos.at[bidx, slot].set(pos_mat)
    return _shard_cache(KVCache(k, v, pos))


def build_cache(k: Array, v: Array, positions: Array, slots: int) -> KVCache:
    """Prefill-path cache construction: keep the last `slots` positions.

    positions must be consecutive per row (prefill), so pos % slots is a
    bijection onto the ring and a plain scatter is exact.
    """
    b, s = k.shape[0], k.shape[1]
    pos_row = positions[0]
    if s >= slots:
        k_w, v_w = k[:, -slots:], v[:, -slots:]
        pos_w = pos_row[-slots:]
    else:
        pad = slots - s
        k_w = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_w = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_w = jnp.concatenate(
            [pos_row, jnp.full((pad,), -1, jnp.int32)])
    slot = jnp.where(pos_w >= 0, pos_w % slots, jnp.arange(slots) % slots)
    kc = jnp.zeros_like(k_w).at[:, slot].set(k_w)
    vc = jnp.zeros_like(v_w).at[:, slot].set(v_w)
    pc = jnp.full((slots,), -1, jnp.int32).at[slot].set(
        pos_w.astype(jnp.int32))
    return _shard_cache(KVCache(kc.astype(jnp.bfloat16),
                                vc.astype(jnp.bfloat16), pc))


def attention(params, cfg: AttnConfig, x: Array, positions: Array,
              cache: Optional[KVCache] = None,
              position_scalar: Optional[Array] = None,
              make_cache_slots: Optional[int] = None):
    """Self-attention (cache=None) or single-step decode (cache given).

    x: (B, S, D); positions: (B, S) absolute. For decode S == 1 and
    position_scalar is the shared scalar position. `make_cache_slots`
    (prefill) builds and returns a decode cache of that many slots.
    Returns (out (B, S, D), new_cache_or_None).
    """
    b, s, d = x.shape
    h, g, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype

    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, g, hd)
    v = v.reshape(b, s, g, hd)
    if cfg.qk_norm:
        q = L.rmsnorm(params["q_norm"], q)
        k = L.rmsnorm(params["k_norm"], k)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    q = shard_act(q, "batch", "seq", "heads", "head_dim")
    k = shard_act(k, "batch", "seq", "heads", "head_dim")
    v = shard_act(v, "batch", "seq", "heads", "head_dim")

    if cache is None:
        if cfg.window > 0 and s > cfg.window:
            # block-banded exact sliding window (§Perf gemma3: avoids the
            # full-sequence KV gather + masked-block scores)
            out = banded_attention(q, k, v, positions, cfg.window)
        else:
            out = blockwise_attention(q, k, v, positions, positions,
                                      window=cfg.window,
                                      kv_block=cfg.kv_block)
        new_cache = (build_cache(k, v, positions, make_cache_slots)
                     if make_cache_slots else None)
    else:
        new_cache = cache_update(cache, k, v, position_scalar)
        if position_scalar is not None and position_scalar.ndim >= 1 \
                and s > 1:
            # per-row chunked prefill: attend over the PRE-update cache
            # plus the appended chunk — mid-chunk queries may need ring
            # entries the chunk's own tail just evicted, and absolute-
            # position masking makes the concat exact (causal within the
            # chunk for free). cache.pos is (B, S): cache_update already
            # requires the per-row layout for vector positions.
            kv_pos = jnp.concatenate(
                [cache.pos, positions.astype(jnp.int32)], axis=1)
            k_cat = jnp.concatenate([cache.k.astype(dt), k], axis=1)
            v_cat = jnp.concatenate([cache.v.astype(dt), v], axis=1)
            out = blockwise_attention(q, k_cat, v_cat, positions, kv_pos,
                                      window=cfg.window,
                                      kv_block=cfg.kv_block)
        else:
            # single-token step (shared or per-row clock): attend over
            # the post-update cache — the only entry a one-token write
            # can evict sits exactly `window` back, already masked out.
            kv_pos = (new_cache.pos if new_cache.pos.ndim == 2 else
                      jnp.broadcast_to(new_cache.pos[None, :],
                                       (b, new_cache.pos.shape[0])))
            out = blockwise_attention(q, new_cache.k.astype(dt),
                                      new_cache.v.astype(dt), positions,
                                      kv_pos, window=cfg.window,
                                      kv_block=cfg.kv_block)
    out = shard_act(out, "batch", "seq", "heads", "head_dim")
    out = out.reshape(b, s, h * hd) @ params["wo"].astype(dt)
    return out, new_cache
