"""Dense building blocks: norms, RoPE, MLPs, embeddings.

Pure-function style: `init_*(key, ...) -> params dict`, `apply(params, x)`.
Parameters are stored fp32 (master copy); compute casts to the config's
activation dtype at use. No framework dependencies.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def truncated_normal(key, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def he_init(key, shape, fan_in, dtype=jnp.float32):
    return truncated_normal(key, shape, (2.0 / max(fan_in, 1)) ** 0.5, dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dt)


def init_groupnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def groupnorm(params, x: Array, groups: int, eps: float = 1e-5) -> Array:
    """GroupNorm over the last dim (RWKV6 per-head wkv normalization)."""
    dt = x.dtype
    d = x.shape[-1]
    xg = x.astype(jnp.float32).reshape(x.shape[:-1] + (groups, d // groups))
    mean = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    y = ((xg - mean) * jax.lax.rsqrt(var + eps)).reshape(x.shape)
    return (y * params["scale"] + params["bias"]).astype(dt)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------

def rope(x: Array, positions: Array, theta: float = 1e4) -> Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,s,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# gated MLPs
# --------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": he_init(k1, (d_model, d_ff), d_model),
        "w_up": he_init(k2, (d_model, d_ff), d_model),
        "w_down": he_init(k3, (d_ff, d_model), d_ff),
    }


def mlp(params, x: Array, act: str = "swiglu") -> Array:
    dt = x.dtype
    wg = params["w_gate"].astype(dt)
    wu = params["w_up"].astype(dt)
    wd = params["w_down"].astype(dt)
    g = x @ wg
    g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
    return (g * (x @ wu)) @ wd


# --------------------------------------------------------------------------
# embeddings / logits
# --------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int):
    return {"table": truncated_normal(key, (vocab, d_model), 0.02)}


def embed(params, tokens: Array, dtype) -> Array:
    return params["table"].astype(dtype)[tokens]


def logits(params, x: Array, tied_table: Optional[Array] = None) -> Array:
    """Final projection; fp32 accumulation for the softmax."""
    table = tied_table if tied_table is not None else params["table"]
    return jnp.einsum("...d,vd->...v", x, table.astype(x.dtype),
                      preferred_element_type=jnp.float32)


def init_unembed(key, vocab: int, d_model: int):
    return {"table": truncated_normal(key, (vocab, d_model), 0.02)}
