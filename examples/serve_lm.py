"""Batched serving example: continuous decode over a mixed request batch.

Serves a small RWKV6 model (O(1)-state decode — the long_500k story at
example scale): requests arrive with different prompt lengths, get bucketed
and prefilled, then decode proceeds as one fused batch with per-request
stop handling. Demonstrates the serve engine the dry-run lowers at
(prefill_32k / decode_32k / long_500k) scale.

    PYTHONPATH=src python examples/serve_lm.py --requests 6 --gen 24
"""

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.reduced_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    kp, kr = jax.random.split(key)
    params = T.init_model(kp, cfg)

    # mixed-length request batch: pad prompts left-aligned into one batch
    rng = np.random.default_rng(args.seed)
    lens = rng.integers(args.max_prompt // 3, args.max_prompt + 1,
                        args.requests)
    b = args.requests
    s = args.max_prompt
    toks = np.zeros((b, s), np.int32)
    for i, ln in enumerate(lens):
        toks[i, s - ln:] = rng.integers(0, cfg.vocab, ln)  # right-aligned

    slots = s + args.gen
    prefill = jax.jit(engine.make_prefill_step(cfg, cache_slots=slots))
    decode = jax.jit(engine.make_decode_step(cfg, args.temperature))

    print(f"[serve_lm] {cfg.name}: {b} requests, prompt lens {lens.tolist()}")
    t0 = time.time()
    logits, caches = prefill(params, {"tokens": jnp.asarray(toks)})
    tok = engine.sample_token(logits, kr, args.temperature)
    t_prefill = time.time() - t0

    outs = [tok]
    eos = cfg.vocab - 1
    done = np.zeros(b, bool)
    t0 = time.time()
    for i in range(args.gen - 1):
        kr, ks = jax.random.split(kr)
        pos = jnp.asarray(s + i, jnp.int32)
        tok, logits, caches = decode(params, caches, {"tokens": tok[:, None]},
                                     pos, ks)
        done |= np.asarray(tok) == eos        # per-request stop bookkeeping
        outs.append(tok)
        if done.all():
            break
    jax.block_until_ready(outs[-1])
    t_decode = time.time() - t0

    gen = np.stack([np.asarray(t) for t in outs], axis=1)
    n_steps = gen.shape[1]
    print(f"[serve_lm] prefill {t_prefill*1e3:.0f} ms; decode {n_steps} "
          f"steps in {t_decode*1e3:.0f} ms "
          f"({b*n_steps/max(t_decode,1e-9):.1f} tok/s batch throughput)")
    for i in range(min(b, 3)):
        print(f"  req {i} (prompt {lens[i]}): {gen[i, :10].tolist()}...")
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print("[serve_lm] OK")


if __name__ == "__main__":
    main()
