"""End-to-end LM training driver: train a ~100M-parameter model.

Trains an RWKV6-family model (the paper-technique core path: every
recurrent layer runs the chunked Squire scan) on the deterministic
synthetic LM stream, with checkpointing, resume, straggler watchdog and
the full loop machinery. Loss decreases from ~ln(V) toward the stream's
conditional entropy.

Presets:
  * ``--preset 100m`` — 12L/768d/~105M params (the brief's end-to-end
    driver; a few hundred steps; hours on CPU, minutes on accelerators).
  * ``--preset 20m``  — 6L/384d/~20M params (CPU-friendly default).
  * ``--preset 3m``   — 4L/128d (CI smoke).

    PYTHONPATH=src python examples/train_lm.py --preset 20m --steps 300
"""

import argparse

import jax

from repro.configs.base import LayerSpec, ModelConfig
from repro.data.lm import DataConfig, TokenStream
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.train.loop import LoopConfig, train

PRESETS = {
    "100m": dict(num_layers=12, d_model=768, d_ff=2688, vocab=8192,
                 batch=8, seq=256),
    "20m": dict(num_layers=6, d_model=384, d_ff=1344, vocab=1024,
                batch=8, seq=128),
    "3m": dict(num_layers=4, d_model=128, d_ff=448, vocab=256,
               batch=8, seq=64),
}


def make_config(p) -> ModelConfig:
    return ModelConfig(
        name=f"rwkv6-train-{p['d_model']}d", family="ssm",
        num_layers=p["num_layers"], d_model=p["d_model"],
        num_heads=p["d_model"] // 64, num_kv_heads=p["d_model"] // 64,
        head_dim=64, d_ff=p["d_ff"], vocab=p["vocab"],
        pattern=(LayerSpec(mixer="rwkv", mlp="rwkv_ffn"),),
        rwkv_head_dim=64, subquadratic=True, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = make_config(p)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    n_params = T.param_count(params)
    del params
    print(f"[train_lm] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"batch={p['batch']} seq={p['seq']} vocab={p['vocab']}")

    ds = TokenStream(DataConfig(vocab=cfg.vocab, batch=p["batch"],
                                seq_len=p["seq"], seed=args.seed))
    res = train(
        cfg, ds.batch,
        LoopConfig(total_steps=args.steps, ckpt_every=max(args.steps // 4, 1),
                   log_every=args.log_every),
        AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 10),
                    decay_steps=args.steps),
        ckpt_dir=args.ckpt_dir, seed=args.seed)

    first, last = res.losses[0], res.losses[-1]
    print(f"\n[train_lm] loss {first:.4f} -> {last:.4f} over "
          f"{res.final_step} steps "
          f"({(first - last):.3f} nats improvement)")
    if args.steps >= 100:
        assert last < first - 0.2, "training did not reduce loss"
        print("[train_lm] OK: loss decreased")
    else:
        print("[train_lm] (short run: loss-decrease assertion needs "
              ">=100 steps)")


if __name__ == "__main__":
    main()
