"""Quickstart: the Squire dependency-decomposition engine in five minutes.

Runs on one CPU device. Shows the paper's three kernel patterns (1-D chain,
2-D wavefront, chunk-parallel sort) through the public API, each in its
sequential ("one worker") and Squire-parallel form, asserting exactness —
then one LM training step whose recurrent layer is the same engine.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import MAXPLUS, affine_scan
from repro.core import chain as chain_lib
from repro.core import dtw as dtw_lib
from repro.core import sort as sort_lib
from repro.data import genomics


def demo_scan1d():
    print("== 1-D recurrence engine (the global counter) ==")
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (1024,))
    b = jax.random.normal(jax.random.PRNGKey(1), (1024,))
    x0 = jnp.zeros(())
    seq = affine_scan(a, b, x0, MAXPLUS, mode="sequential")
    chk = affine_scan(a, b, x0, MAXPLUS, mode="chunked", num_chunks=16)
    par = affine_scan(a, b, x0, MAXPLUS, mode="associative")
    assert np.allclose(seq, chk, atol=1e-4) \
        and np.allclose(seq, par, atol=1e-4)
    print("  sequential == chunked(16 workers) == associative: exact "
          "(up to fp32 reassociation)\n")


def demo_chain():
    print("== Chain kernel (minimap2, paper Alg. 2/3) ==")
    q, r = genomics.anchor_set(2000, seed=0)
    f_seq, p_seq = chain_lib.chain_anchors(jnp.asarray(q), jnp.asarray(r),
                                           T=64, mode="sequential")
    f_blk, p_blk = chain_lib.chain_anchors(jnp.asarray(q), jnp.asarray(r),
                                           T=64, mode="blocked")
    assert np.allclose(f_seq, f_blk, atol=1e-4)
    chains = chain_lib.backtrack(np.asarray(f_seq), np.asarray(p_seq))
    print(f"  2000 anchors -> best chain score "
          f"{float(jnp.max(f_seq)):.1f}, {len(chains)} chains; "
          "sequential == blocked: exact\n")


def demo_dtw():
    print("== DTW (paper Alg. 4) on the tiled wavefront ==")
    key = jax.random.PRNGKey(2)
    s = jax.random.normal(key, (128,))
    r = jax.random.normal(jax.random.PRNGKey(3), (160,))
    ref = dtw_lib.dtw_ref(s, r)
    mat, dist = dtw_lib.dtw_tiled(s, r, tile_r=32, tile_c=32)
    assert np.allclose(mat, ref, atol=1e-4)
    print(f"  DTW distance {float(dist):.2f}; "
          "tiled wavefront == sequential: exact\n")


def demo_sort():
    print("== Chunk-parallel radix sort (paper Alg. 1) ==")
    keys = jax.random.randint(jax.random.PRNGKey(4), (50_000,), 0,
                              2**31 - 1, dtype=jnp.int32).astype(jnp.uint32)
    sk, sv = sort_lib.radix_sort(keys, num_chunks=8)
    assert np.array_equal(np.asarray(sk), np.sort(np.asarray(keys)))
    print("  50k keys, 8 worker chunks + parallel merge == jnp.sort\n")


def demo_lm_step():
    print("== One LM train step (RWKV6: the engine at LM scale) ==")
    from repro import configs
    from repro.optim import AdamWConfig
    from repro.train import init_train_state, make_train_step

    cfg = configs.reduced_config("rwkv6-1.6b")
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig()))
    batch = {"tokens": jnp.zeros((2, 64), jnp.int32),
             "labels": jnp.zeros((2, 64), jnp.int32)}
    state, metrics = step(state, batch)
    print(f"  loss={float(metrics['loss']):.3f} "
          f"grad_norm={float(metrics['grad_norm']):.3f} — "
          "the WKV layer runs core.linear_attn (chunked Squire scan)\n")


if __name__ == "__main__":
    demo_scan1d()
    demo_chain()
    demo_dtw()
    demo_sort()
    demo_lm_step()
    print("quickstart: all demos passed")
