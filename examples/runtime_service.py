"""KernelService demo: heterogeneous dependency-bound kernel traffic
through the batched runtime (the software Squire accelerator pool).

Builds a mixed workload — chain, Smith-Waterman, DTW, radix sort, 1-D
scans, plus end-to-end read mapping against a synthetic reference — and
serves it twice: one request at a time (per-request dispatch, the
1-caller configuration the paper starts from) and as one bulk
``submit`` (bucketed, batched, pipelined). Results are asserted
identical; the wall-clock ratio is the dispatch-layer win.

    PYTHONPATH=src python examples/runtime_service.py [--requests 64]

Observability (PR 6): ``--trace out.json`` records every bucket
dispatch as Chrome trace events on the dispatcher track (load in
https://ui.perfetto.dev); ``--metrics`` dumps the metrics registry —
``runtime.dispatch.*`` compile-cache hit/miss counts and
compile-vs-execute wall time, per-bucket splits, pipeline fence times,
and per-kernel request counts — as JSON on exit.
"""

import argparse
import json
import time

import numpy as np

from repro.data import genomics
from repro.obs import REGISTRY, Tracer, get_tracer, set_tracer
from repro.runtime import KernelService, Request, ServiceConfig


def make_workload(rng, n_requests: int, ref: np.ndarray):
    """A traffic-like mix: mostly light kernels, a few end-to-end maps."""
    reqs = []
    prof = genomics.ReadProfile("DEMO", 350, 60, 0.93)
    reads = [r for r, _ in genomics.sample_reads(ref, prof,
                                                 max(n_requests // 8, 1),
                                                 seed=7)]
    for i in range(n_requests):
        kind = i % 5
        if kind == 0:
            n = int(rng.integers(64, 256))
            reqs.append(Request("chain", {
                "q": np.sort(rng.integers(0, 400, n)).astype(np.int32),
                "r": np.sort(rng.integers(0, 5000, n)).astype(np.int32)}))
        elif kind == 1:
            reqs.append(Request("sw", {
                "a": rng.integers(0, 4, int(rng.integers(24, 96))),
                "b": rng.integers(0, 4, int(rng.integers(24, 96)))}))
        elif kind == 2:
            reqs.append(Request("dtw", {
                "s": rng.normal(size=int(rng.integers(24, 64))),
                "r": rng.normal(size=int(rng.integers(24, 64)))}))
        elif kind == 3:
            reqs.append(Request("sort", {
                "keys": rng.integers(0, 2**32, int(rng.integers(50, 400)),
                                     dtype=np.uint32)}))
        else:
            t = int(rng.integers(16, 64))
            reqs.append(Request("scan1d", {
                "a": rng.normal(size=t).astype(np.float32),
                "b": rng.normal(size=t).astype(np.float32),
                "x0": np.float32(0.0)}))
    for rd in reads:
        reqs.append(Request("map", {"read": rd}))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--ref", type=int, default=12_000)
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="record bucket dispatches as a Chrome trace "
                         "(open in https://ui.perfetto.dev)")
    ap.add_argument("--metrics", action="store_true",
                    help="dump the metrics registry as JSON on exit")
    args = ap.parse_args()

    if args.trace:
        set_tracer(Tracer(enabled=True))

    rng = np.random.default_rng(0)
    ref = genomics.make_reference(args.ref, seed=0)
    svc = KernelService(ServiceConfig(dtw_tile=16, sw_tile=16,
                                      seq_bucket=64), reference=ref)
    reqs = make_workload(rng, args.requests, ref)
    kinds = sorted({r.kernel for r in reqs})
    print(f"workload: {len(reqs)} requests over kernels {kinds}")

    print("warming compile caches (one program per kernel x bucket)...")
    svc.submit(reqs)
    singles = []
    for r in reqs:
        singles.extend(svc.submit([r]))

    t0 = time.perf_counter()
    batched = svc.submit(reqs)
    t_batch = time.perf_counter() - t0
    t0 = time.perf_counter()
    for r in reqs:
        svc.submit([r])
    t_single = time.perf_counter() - t0

    same = all(
        a == b if not isinstance(a, dict)
        else all(np.array_equal(a[k], b[k]) for k in a)
        for a, b in zip(batched, singles))
    print(f"batched submit   : {len(reqs)/t_batch:8.0f} req/s "
          f"({t_batch*1e3:.0f} ms)")
    print(f"per-request loop : {len(reqs)/t_single:8.0f} req/s "
          f"({t_single*1e3:.0f} ms)")
    print(f"dispatch speedup : {t_single/t_batch:.2f}x; "
          f"results identical: {same}")

    mapped = [r for r, req in zip(batched, reqs) if req.kernel == "map"]
    if mapped:
        ok = sum(1 for m in mapped if m.pos >= 0)
        print(f"mapper           : {ok}/{len(mapped)} reads mapped "
              f"(batched seed->chain->align)")

    if args.trace:
        get_tracer().export_chrome(args.trace)
        print(f"trace            : {args.trace} "
              f"({len(get_tracer().events)} events; "
              f"load in https://ui.perfetto.dev)")
    if args.metrics:
        print(json.dumps(REGISTRY.snapshot(), indent=1, sort_keys=True))


if __name__ == "__main__":
    main()
