"""End-to-end read mapping (paper §VI-C, Fig. 8) — seed -> chain -> align.

Builds a synthetic reference, samples reads with the paper's five input
profiles (Table IV statistics), and maps them with the baseline (1-worker)
and Squire (chunk-parallel) pipelines, reporting accuracy and wall-clock.
Both pipelines are exact transformations of each other, so accuracies
match; the wall-clock ratio on CPU is a *proxy* for the paper's Fig. 8
(gem5 cycle numbers need silicon).

    PYTHONPATH=src python examples/read_mapper.py [--reads 4] [--ref 20000]
"""

import argparse
import time

import numpy as np

from repro.apps.read_mapper import MapperConfig, ReadMapper, mapping_accuracy
from repro.data import genomics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ref", type=int, default=20_000)
    ap.add_argument("--reads", type=int, default=4)
    ap.add_argument("--profiles", nargs="*",
                    default=["ONT", "PBHF1"])
    ap.add_argument("--scale", type=float, default=0.25,
                    help="read-length scale vs Table IV/10 (CPU wall-clock)")
    args = ap.parse_args()

    ref = genomics.make_reference(args.ref, seed=0)

    for prof_name in args.profiles:
        base = genomics.PROFILE_BY_NAME[prof_name]
        prof = genomics.ReadProfile(
            base.name, max(300, int(base.mean_len * args.scale)),
            max(80, int(base.std_len * args.scale)), base.accuracy)
        pairs = genomics.sample_reads(ref, prof, args.reads, seed=1)
        reads = [r for r, _ in pairs]
        truths = [t for _, t in pairs]

        print(f"\n=== profile {prof.name} (len~{prof.mean_len}, "
              f"acc {prof.accuracy:.4f}) ===")
        rows = {}
        for mode in ("baseline", "squire"):
            mapper = ReadMapper(ref, MapperConfig(mode=mode))
            mapper.map_read(reads[0])          # warm the shape buckets
            t0 = time.time()
            res = mapper.map_reads(reads)
            dt = time.time() - t0
            acc = mapping_accuracy(res, truths)
            cells = sum(r.align_cells for r in res)
            rows[mode] = (dt, acc, res)
            print(f"  {mode:9s}: {dt:6.2f}s  accuracy={acc:.2f}  "
                  f"align_cells={cells/1e6:.2f}M")
        sp = rows["baseline"][0] / max(rows["squire"][0], 1e-9)
        same = all(a.pos == b.pos and abs(a.sw_score - b.sw_score) < 1e-3
                   for a, b in zip(rows["baseline"][2], rows["squire"][2]))
        print(f"  squire speedup (CPU proxy): {sp:.2f}x; "
              f"outputs identical: {same}")


if __name__ == "__main__":
    main()
