"""Continuous-batching serving demo: requests arrive mid-stream, slots
recycle per decode step.

A 4-slot pool serves 10 mixed-length requests that arrive in waves. Watch
the slot lifecycle: a request is admitted the moment a slot frees (no
pad-to-the-slowest batch), its prompt is consumed as full chunks through
the batched chunk step plus a teacher-forced decode ramp, and EOS /
max-tokens eviction hands the slot to the next arrival on the same tick.
Repeat prompts at the end hit the memoizing request cache and finish
without touching the pool.

    PYTHONPATH=src python examples/serve_continuous.py --requests 10

Observability (PR 6): ``--trace out.json`` records the serve as Chrome
trace events (load in https://ui.perfetto.dev — one track per slot plus
scheduler/dispatcher tracks); ``--metrics`` dumps the flat metrics
registry (``serve.*``, ``serve.engine.*``, paging) as JSON on exit.

Sharded pool: ``--mesh N`` (with ``--paged``) splits the pool into N
shards, each owning ``--slots`` slots and its own block pool; requests
are placed on the least-loaded shard and blocked queue heads migrate to
idle shards (work stealing). With >= N devices (e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) the fused steps
run through a real shard_map mesh, otherwise the vmap path — streams
are identical either way.

Closed loop (PR 7): ``--sample out.jsonl`` installs a live Sampler
ticking off every scheduler step and exports the sample ring as a JSONL
time-series (with ``--trace`` the levels also land as Perfetto counter
tracks); ``--slo`` wires a queue-wait SLO monitor with hysteresis to a
BackpressureController — pair with ``--paged --num-blocks <small>`` and
watch the admission cap engage while the alert fires and release when
the queue drains.
"""

import argparse
import json
import time

import numpy as np

import jax

from repro import configs
from repro.models import transformer as T
from repro.obs import (REGISTRY, BackpressureController, Rule, Sampler,
                       SLOManager, Tracer, set_sampler, set_tracer)
from repro.serve import Scheduler, SchedulerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=40)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="block-granular slot allocator (try with an "
                         "attention arch, e.g. --arch gemma-2b)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="paged: shrink below the equal-memory default "
                         "to watch preemptions happen")
    ap.add_argument("--num-window-blocks", type=int, default=None,
                    help="paged: block budget for window-ring groups "
                         "(try --arch gemma3-12b — its sliding-window "
                         "rings page next to the global KV)")
    ap.add_argument("--dense-windows", action="store_true",
                    help="paged: keep sliding-window rings dense per "
                         "slot instead of paging them")
    ap.add_argument("--swap-budget", type=int, default=None,
                    help="preempt=swap: SwapStore byte cap — over-budget "
                         "victims fall back to recompute")
    ap.add_argument("--preempt", choices=["recompute", "swap"],
                    default="recompute",
                    help="paged: what preempt-on-OOB discards — 'swap' "
                         "parks the victim's blocks host-side and "
                         "resumes it with zero recomputed decode steps")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="paged: shard the pool over N shards (--slots "
                         "slots + --num-blocks blocks EACH); uses a real "
                         "device mesh when >= N devices exist, the vmap "
                         "path otherwise")
    ap.add_argument("--reserved", action="store_true",
                    help="paged: book blocks for prompt+max_new at "
                         "admission (QoS: admitted requests are never "
                         "preempted)")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="record a Chrome trace of the serve to OUT.json "
                         "(open in https://ui.perfetto.dev)")
    ap.add_argument("--metrics", action="store_true",
                    help="dump the metrics registry as JSON on exit")
    ap.add_argument("--sample", metavar="OUT.jsonl", default=None,
                    help="live-sample the registry every scheduler step "
                         "and export the ring as JSONL (with --trace the "
                         "levels also become Perfetto counter tracks)")
    ap.add_argument("--slo", action="store_true",
                    help="close the loop: a queue-wait SLO monitor drives "
                         "a BackpressureController (admission cap + swap "
                         "preempt while firing; restored on clear)")
    args = ap.parse_args()

    if args.mesh and not args.paged:
        ap.error("--mesh requires --paged (shards own block pools)")

    if args.trace:
        set_tracer(Tracer(enabled=True))

    cfg = configs.reduced_config(args.arch)
    params = T.init_model(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)

    mesh = None
    if args.mesh > 1 and jax.device_count() >= args.mesh:
        from repro.launch import mesh as mesh_lib
        mesh = mesh_lib.make_worker_mesh(args.mesh, axis="slots")

    sched = Scheduler(cfg, params, SchedulerConfig(
        num_slots=args.slots, max_len=args.max_prompt + args.max_new + 8,
        prefill_chunk=16, eos_token=cfg.vocab - 1,
        allocator="paged" if args.paged else "contiguous",
        block_size=args.block_size, num_blocks=args.num_blocks,
        paged_window_attn=not args.dense_windows,
        num_window_blocks=args.num_window_blocks,
        swap_bytes_budget=args.swap_budget,
        preempt=args.preempt,
        mesh_shards=args.mesh or None,
        admission="reserved" if args.reserved else "optimistic"),
        mesh=mesh)
    if args.mesh:
        path = (f"shard_map over {args.mesh} devices" if mesh is not None
                else "vmap (single device)")
        print(f"[serve_continuous] sharded pool: {args.mesh} shards x "
              f"{args.slots} slots, {path}")

    smp = slo = None
    if args.sample or args.slo:
        smp = Sampler(counter_tracks=(
            ("serve.pending", "value"), ("serve.live", "value"),
            ("serve.generated_tokens", "rate")) if args.trace else ())
        set_sampler(smp)
    if args.slo:
        slo = SLOManager([Rule("queue_wait",
                               key="serve.queue_head_wait_s", op="<",
                               threshold=0.01, fire_after=2,
                               clear_after=2)])
        ctrl = BackpressureController(sched, admit_cap=1, preempt="swap")
        slo.subscribe(ctrl)
        smp.add_listener(slo.on_sample)

    prompts = [rng.integers(0, cfg.vocab,
                            int(rng.integers(4, args.max_prompt))
                            ).astype(np.int32)
               for _ in range(args.requests)]
    budgets = [int(rng.integers(2, args.max_new)) for _ in prompts]

    print(f"[serve_continuous] {cfg.name}: pool={args.slots} slots, "
          f"{args.requests} requests, prompt lens "
          f"{[len(p) for p in prompts]}")

    # wave 1 now, wave 2 after a few ticks — arrivals interleave decode
    half = len(prompts) // 2
    t0 = time.time()
    for p, m in zip(prompts[:half], budgets[:half]):
        sched.submit([p], max_new_tokens=m)
    tick = 0
    submitted = half
    while sched.pending or sched.live or submitted < len(prompts):
        done = sched.step()
        tick += 1
        for c in done:
            print(f"  t={tick:3d} rid={c.rid} done ({c.reason}): "
                  f"{len(c.tokens)} tokens, latency {c.latency*1e3:.0f} ms")
        if tick % 5 == 0 and submitted < len(prompts):   # wave 2 trickles in
            sched.submit([prompts[submitted]],
                         max_new_tokens=budgets[submitted])
            print(f"  t={tick:3d} arrival rid={submitted} "
                  f"(live={sched.live}, free={sched.slots.free_count})")
            submitted += 1
    wall = time.time() - t0

    # zipfian repeats: served from the request cache, zero decode steps
    rep = sched.submit([prompts[0], prompts[0], prompts[0]],
                       max_new_tokens=budgets[0])
    sched.drain()
    st = sched.stats()
    print(f"[serve_continuous] {st['completed']} servings in {wall:.1f}s "
          f"({st['generated_tokens']} tokens, {st['decode_steps']} decode "
          f"steps, {st['chunk_steps']} chunk steps)")
    print(f"[serve_continuous] repeat submits: "
          f"{[sched.results[r].reason for r in rep]} "
          f"(cache hit rate {sched.request_cache.hit_rate:.2f})")
    if args.paged:
        rings = {k: v for k, v in st.items()
                 if k.startswith("ring") and k.endswith("_total")}
        print(f"[serve_continuous] paged allocator: "
              f"{st['blocks_total']} blocks x {st['block_size']} positions "
              f"in {st['page_groups']} page-table group(s)"
              + (f" (window rings: {rings})" if rings else "") + ", "
              f"{st.get('preempted', 0)} preemptions "
              f"({args.preempt}: {st.get('recomputed_decode_steps', 0)} "
              f"recomputed decode steps, "
              f"{st.get('swap_bytes_out', 0)} bytes swapped out, "
              f"{st.get('swap_rejected', 0)} swap rejections), "
              f"mean occupancy {st.get('mean_occupancy', 0):.2f}")
    if args.mesh:
        sm = sched._shard_obs.metrics()
        per = [f"shard{s}: placed={sm[f'shard{s}.placed']} "
               f"stolen_in={sm[f'shard{s}.steals']} "
               f"blocks_used={sm[f'shard{s}.blocks_used']}"
               for s in range(sm["num_shards"])]
        print(f"[serve_continuous] shards ({sm['steals']} steals): "
              + "; ".join(per))
    if args.trace:
        from repro.obs import get_tracer
        get_tracer().export_chrome(args.trace)
        print(f"[serve_continuous] trace -> {args.trace} "
              f"({len(get_tracer().events)} events; "
              f"load in https://ui.perfetto.dev)")
    if args.slo:
        snap = REGISTRY.snapshot()
        print(f"[serve_continuous] closed loop: queue_wait fired "
              f"{snap['obs.slo.queue_wait.fired']}x, backpressure "
              f"engaged {snap['obs.control.backpressure.engaged']}x "
              f"(firing now: {slo.monitors['queue_wait'].firing})")
    if args.sample:
        smp.export_jsonl(args.sample)
        print(f"[serve_continuous] samples -> {args.sample} "
              f"({smp.sample_count} samples, "
              f"{len(smp.samples)} retained)")
    if args.metrics:
        print(json.dumps(REGISTRY.snapshot(), indent=1, sort_keys=True))
    print("[serve_continuous] OK")


if __name__ == "__main__":
    main()
